"""BatchDecoder: parity vs the host decoder, bucket boundaries, compile
bounds, and the pre-concatenated device-stream entry point.  (Tentpole
coverage for the batched bucketed decode engine.)"""
import numpy as np
import pytest

from _synth import uniform_code_container as _uniform_code_container
from repro.core import DOMAIN_DEFAULTS, calibrate, decode, encode
from repro.core.symlen import unpack_symlen_np, PackedStream
from repro.data import make_signal
from repro.serving.batch_decode import (
    BatchDecoder,
    StreamGroup,
    bucket_cache_size,
    streams_from_containers,
)
from repro.serving.engine import p2, symlen_bucket


def _shards(engine_obj) -> int:
    """Visible shard count: dispatch-count assertions scale by it so the
    suite stays valid under the multi-device CI leg
    (XLA_FLAGS=--xla_force_host_platform_device_count=4)."""
    return engine_obj.scheduler.num_shards


def _expected_dispatches(engine_obj, group_sizes) -> int:
    """One fused dispatch per (group, shard): each group splits into at
    most num_shards contiguous shards."""
    k = _shards(engine_obj)
    return sum(min(size, k) for size in group_sizes)


@pytest.fixture(scope="module")
def power_tables():
    return calibrate(
        make_signal("load_power", 65536, seed=7),
        DOMAIN_DEFAULTS["power"],
        domain_id=0,
    )


@pytest.fixture(scope="module")
def meteo_tables():
    return calibrate(
        make_signal("temperature", 65536, seed=8),
        DOMAIN_DEFAULTS["meteorological"],
        domain_id=1,
    )


def _batch_parity(containers, tables_arg, per_container_tables, *,
                  use_kernels=False, atol=1e-4):
    dec = BatchDecoder(use_kernels=use_kernels)
    outs = dec.decode(containers, tables_arg).to_host()
    assert len(outs) == len(containers)
    for c, out, tab in zip(containers, outs, per_container_tables):
        ref = decode(c, tab)
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, atol=atol)
    return dec


def test_single_domain_mixed_lengths(power_tables):
    lengths = [4096, 16384, 5000, 8191, 333]
    cs = [
        encode(make_signal("load_power", n, seed=i), power_tables)
        for i, n in enumerate(lengths)
    ]
    dec = _batch_parity(cs, power_tables, [power_tables] * len(cs))
    # one (domain, config) group -> one fused dispatch per shard
    assert dec.stats.dispatches == _expected_dispatches(dec, [len(cs)])


def test_mixed_domain_batch(power_tables, meteo_tables):
    cs, per = [], []
    for i, n in enumerate([4096, 6000, 12288, 3001]):
        if i % 2 == 0:
            cs.append(encode(make_signal("load_power", n, seed=i),
                             power_tables))
            per.append(power_tables)
        else:
            cs.append(encode(make_signal("temperature", n, seed=i),
                             meteo_tables))
            per.append(meteo_tables)
    dec = _batch_parity(cs, {0: power_tables, 1: meteo_tables}, per)
    # one per (domain, config) group, times the shard split
    assert dec.stats.dispatches == _expected_dispatches(dec, [2, 2])


def test_batch_of_one_matches_decode_device(power_tables):
    from repro.core import decode_device

    c = encode(make_signal("load_power", 10000, seed=3), power_tables)
    np.testing.assert_allclose(
        decode_device(c, power_tables), decode(c, power_tables), atol=1e-4
    )


def test_use_kernels_interpret_parity(power_tables, meteo_tables):
    cs = [
        encode(make_signal("load_power", 4096, seed=21), power_tables),
        encode(make_signal("temperature", 3000, seed=22), meteo_tables),
    ]
    _batch_parity(
        cs, {0: power_tables, 1: meteo_tables},
        [power_tables, meteo_tables], use_kernels=True,
    )


def test_bit_exact_symbol_parity(power_tables, meteo_tables):
    """The concatenated-stream symbol stage reproduces the host decoder's
    symbol stream bit for bit (acceptance criterion)."""
    import jax.numpy as jnp

    from repro.core import symlen as symlib

    cs = [
        encode(make_signal("load_power", 9000, seed=31), power_tables),
        encode(make_signal("load_power", 4096, seed=32), power_tables),
        encode(make_signal("load_power", 777, seed=33), power_tables),
    ]
    # host reference: per-container serial LUT decode, concatenated
    ref = np.concatenate([
        unpack_symlen_np(
            PackedStream(
                words=c.words, symlen=c.symlen.astype(np.int32),
                num_symbols=c.num_symbols,
            ),
            power_tables.book,
        )
        for c in cs
    ])
    # engine path: concatenated words + one segment-aware scatter compaction
    hi = np.concatenate([c.words_u32()[0] for c in cs])
    lo = np.concatenate([c.words_u32()[1] for c in cs])
    sl = np.concatenate([c.symlen.astype(np.int32) for c in cs])
    dev = power_tables.device_tables()
    got = symlib.unpack_symlen(
        jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(sl),
        dev.dec_limit, dev.dec_first, dev.dec_rank, dev.dec_syms,
        l_max=cs[0].l_max,
        max_symlen=max(c.max_symlen for c in cs),
        num_symbols=int(ref.size),
    )
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.parametrize("num_words", [255, 256, 257])
def test_bucket_boundary_word_counts(num_words):
    """Exactly at / one over a power-of-two word count decodes correctly
    (the padding words must contribute zero symbols)."""
    c, tables = _uniform_code_container(num_words, seed=num_words)
    ref = decode(c, tables)
    dec = BatchDecoder()
    out = dec.decode([c], tables).to_host()[0]
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_bucket_boundary_batch_mix():
    """A batch whose total word count lands one over a power of two."""
    c1, tables = _uniform_code_container(256, seed=1)
    c2, _ = _uniform_code_container(257, seed=2)
    dec = BatchDecoder()
    outs = dec.decode([c1, c2], tables).to_host()
    np.testing.assert_allclose(outs[0], decode(c1, tables), atol=1e-4)
    np.testing.assert_allclose(outs[1], decode(c2, tables), atol=1e-4)
    assert dec.stats.dispatches == _expected_dispatches(dec, [2])


def test_mixed_64_container_archive_compile_bound(power_tables, meteo_tables):
    """Acceptance: a mixed batch of 64 containers (2 domains, varied
    lengths) decodes with a bounded number of fused dispatches and at most
    6 fresh XLA specializations of the bucket decode."""
    rng = np.random.default_rng(0)
    cs = []
    for i in range(64):
        length = int(rng.integers(1024, 8192))
        if i % 2 == 0:
            cs.append(encode(
                make_signal("load_power", length, seed=200 + i), power_tables
            ))
        else:
            cs.append(encode(
                make_signal("temperature", length, seed=200 + i), meteo_tables
            ))
    before = bucket_cache_size()
    dec = BatchDecoder()
    outs = dec.decode(cs, {0: power_tables, 1: meteo_tables}).to_host()
    after = bucket_cache_size()
    k = _shards(dec)
    # one dispatch per (domain, config) group per shard; sharding splits
    # word totals, so the compile bound scales with the shard count too
    assert dec.stats.dispatches <= 6 * k
    if before is not None and after is not None:
        assert after - before <= 6 * k, f"{after - before} fresh compilations"
    # spot-check parity on a few members
    for i in (0, 1, 31, 63):
        tab = power_tables if i % 2 == 0 else meteo_tables
        np.testing.assert_allclose(outs[i], decode(cs[i], tab), atol=1e-4)


def test_order_preserved_and_device_access(power_tables, meteo_tables):
    cs = [
        encode(make_signal("temperature", 2048, seed=41), meteo_tables),
        encode(make_signal("load_power", 4096, seed=42), power_tables),
        encode(make_signal("temperature", 1024, seed=43), meteo_tables),
    ]
    dec = BatchDecoder()
    batch = dec.decode(cs, {0: power_tables, 1: meteo_tables})
    outs = batch.to_host()
    assert [o.shape[0] for o in outs] == [2048, 4096, 1024]
    # lazy device slices agree with the host drain
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(batch.device_signal(i)), outs[i], atol=0
        )


def test_empty_batch():
    dec = BatchDecoder()
    batch = dec.decode([], {})
    assert len(batch) == 0 and batch.to_host() == []


def test_plan_cache_reuse(power_tables):
    dec = BatchDecoder()
    c = encode(make_signal("load_power", 2048, seed=51), power_tables)
    dec.decode([c], power_tables).to_host()
    dec.decode([c], power_tables).to_host()
    assert dec.stats.plan_misses == 1
    assert dec.stats.plan_hits >= 1


def test_bucket_helpers():
    assert [p2(x) for x in (1, 2, 3, 255, 256, 257)] == [
        1, 2, 4, 256, 256, 512
    ]
    assert symlen_bucket(1) == 8
    assert symlen_bucket(33) == 40
    assert symlen_bucket(64) == 64
    assert symlen_bucket(100) == 64


# ---------------------------------------------------------------------------
# decode_streams: the pre-concatenated (device) stream entry point.
# ---------------------------------------------------------------------------
def test_decode_streams_matches_decode(power_tables, meteo_tables):
    """Feeding streams_from_containers output through decode_streams gives
    exactly what decode() gives (it IS decode's internal path), in group
    member order."""
    cs = [
        encode(make_signal("temperature", 2048, seed=61), meteo_tables),
        encode(make_signal("load_power", 4096, seed=62), power_tables),
        encode(make_signal("temperature", 1000, seed=63), meteo_tables),
    ]
    tables = {0: power_tables, 1: meteo_tables}
    groups, member_pos = streams_from_containers(cs)
    assert [g.plan_key[0] for g in groups] == [1, 0]  # first-appearance order
    assert member_pos == [0, 2, 1]  # meteo members first, then power

    dec = BatchDecoder()
    outs = dec.decode_streams(groups, tables).to_host()
    ref = BatchDecoder().decode(cs, tables).to_host()
    for i in range(len(cs)):
        np.testing.assert_array_equal(outs[member_pos[i]], ref[i])


def test_decode_streams_oversized_padding_is_harmless(power_tables):
    """Extra zero words (symlen == 0) beyond the live stream — the situation
    a bound-sized device stitch produces — decode to the same signals."""
    import jax.numpy as jnp

    c = encode(make_signal("load_power", 3000, seed=64), power_tables)
    groups, _ = streams_from_containers([c])
    g = groups[0]
    pad = 277  # deliberately not a power of two
    grp = StreamGroup(
        plan_key=g.plan_key,
        hi=jnp.pad(g.hi, (0, pad)),
        lo=jnp.pad(g.lo, (0, pad)),
        symlen=jnp.pad(g.symlen, (0, pad)),
        max_symlen=64,  # a loose bound must also be safe
        members=g.members,
    )
    out = BatchDecoder().decode_streams([grp], power_tables).to_host()[0]
    # word-axis padding and a loose slot bound change integer work only —
    # the decoded samples must match the unpadded engine bit for bit
    ref = BatchDecoder().decode([c], power_tables).to_host()[0]
    np.testing.assert_array_equal(out, ref)
    np.testing.assert_allclose(out, decode(c, power_tables), atol=1e-4)


def test_decode_streams_validates_tables(power_tables, meteo_tables):
    c = encode(make_signal("load_power", 512, seed=65), power_tables)
    groups, _ = streams_from_containers([c])
    with pytest.raises(ValueError, match="plan_key"):
        BatchDecoder().decode_streams(groups, meteo_tables)
