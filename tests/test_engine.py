"""Shared serving-engine layer (tentpole coverage): scheduler grouping +
shard assignment, the double-buffered PipelineExecutor, and the
cross-engine guarantees the refactor rests on — pipelining, sharding,
bucket policies and kernel block tuning change *when/where* buckets run,
never the produced bytes, and add no device->host syncs before the single
drain."""
import threading
from collections import defaultdict

import jax
import numpy as np
import pytest

import repro.serving.batch_decode as batch_decode_mod
import repro.serving.batch_encode as batch_encode_mod
from repro.core import DOMAIN_DEFAULTS, calibrate, encode
from repro.data import make_signal
from repro.serving import (
    BatchDecoder,
    BatchEncoder,
    BucketScheduler,
    PipelineExecutor,
    Transcoder,
    serving_devices,
)
from repro.serving.engine import _split_balanced, member_positions
from repro.tuning.policy import POLICY_NAMES


# ---------------------------------------------------------------------------
# Scheduler units.
# ---------------------------------------------------------------------------
def test_group_by_first_appearance_order():
    order, groups = BucketScheduler.group_by(["b", "a", "b", "c", "a"])
    assert order == ["b", "a", "c"]
    assert groups == {"b": [0, 2], "a": [1, 4], "c": [3]}


def test_buckets_single_shard_matches_grouping():
    sched = BucketScheduler(devices=None)
    buckets = sched.buckets(["x", "y", "x", "x"])
    assert [(b.key, list(b.items)) for b in buckets] == [
        ("x", [0, 2, 3]), ("y", [1])
    ]
    assert all(b.shard == 0 and b.device is None for b in buckets)
    assert member_positions(buckets, 4) == [0, 3, 1, 2]


def test_buckets_contiguous_balanced_shards():
    # fake "devices": scheduling never touches them unless work dispatches
    sched = BucketScheduler(devices=["d0", "d1"])
    assert sched.num_shards == 2
    buckets = sched.buckets(["x"] * 5 + ["y"])
    assert [(b.key, b.shard, list(b.items)) for b in buckets] == [
        ("x", 0, [0, 1, 2]), ("x", 1, [3, 4]), ("y", 0, [5])
    ]
    assert buckets[1].device == "d1"
    # flattened member order is still group-major, members in input order
    assert member_positions(buckets, 6) == [0, 1, 2, 3, 4, 5]


def test_buckets_rotate_start_shard_across_groups():
    """Many small groups spread over every device: the starting shard
    rotates, instead of every single-member group landing on shard 0."""
    sched = BucketScheduler(devices=["d0", "d1", "d2", "d3"])
    buckets = sched.buckets(["a", "b", "c", "d", "e"])
    assert [b.shard for b in buckets] == [0, 1, 2, 3, 0]


def test_buckets_pinned_shard_ids():
    sched = BucketScheduler(devices=["d0", "d1", "d2"])
    buckets = sched.buckets(
        ["x", "x", "x", "y"], shard_ids=[2, 0, 2, 1]
    )
    assert [(b.key, b.shard, list(b.items)) for b in buckets] == [
        ("x", 0, [1]), ("x", 2, [0, 2]), ("y", 1, [3])
    ]


def test_scheduler_round_follows_policy(monkeypatch):
    # pin the env so the default-policy assertion holds under the CI
    # tuning leg (which exports FPTC_BUCKET_POLICY=cost-balanced)
    monkeypatch.delenv("FPTC_BUCKET_POLICY", raising=False)
    assert BucketScheduler(devices=None).round(5) == 8  # p2 default
    assert BucketScheduler(devices=None, policy="half-octave").round(5) == 6
    assert BucketScheduler(devices=None, policy="cost-balanced").round(5) == 5
    sched = BucketScheduler(devices=None, policy="half-octave")
    for x in (1, 2, 3, 7, 100, 1000):
        r = sched.round(x)
        assert r >= x
        assert sched.round(r) == r  # idempotent on edges


def test_split_balanced_equal_costs_stay_balanced():
    parts = _split_balanced(list(range(10)), [1.0] * 10, 4)
    assert sum(parts, []) == list(range(10))  # contiguous, order kept
    sizes = sorted(len(p) for p in parts)
    assert len(parts) == 4 and sizes[-1] - sizes[0] <= 1


def test_split_balanced_isolates_heavy_item():
    # one item worth more than everything else combined gets its own shard
    parts = _split_balanced([0, 1, 2, 3], [100.0, 1.0, 1.0, 1.0], 2)
    assert parts == [[0], [1, 2, 3]]


def test_split_balanced_degenerate_falls_back():
    from repro.serving.engine import _split_contiguous

    assert _split_balanced([0, 1], [1.0, 1.0], 1) == (
        _split_contiguous([0, 1], 1)
    )
    assert _split_balanced([0, 1], [0.0, 0.0], 2) == (
        _split_contiguous([0, 1], 2)
    )


def test_buckets_cost_balanced_shard_split():
    sched = BucketScheduler(devices=["d0", "d1"])
    buckets = sched.buckets(
        ["x", "x", "x", "x"], item_costs=[100.0, 1.0, 1.0, 1.0]
    )
    assert [(b.shard, list(b.items)) for b in buckets] == [
        (0, [0]), (1, [1, 2, 3])
    ]


def test_serving_devices_resolution():
    assert serving_devices(None) == (None,)
    local = jax.local_devices()
    auto = serving_devices("auto")
    # shard 0 keeps default (uncommitted) placement so batch-of-one work
    # through the default engines honors jax.default_device
    assert auto == ((None, *local[1:]) if len(local) > 1 else (None,))
    assert serving_devices(local) == tuple(local)
    with pytest.raises(ValueError, match="non-empty"):
        serving_devices([])


# ---------------------------------------------------------------------------
# Executor units.
# ---------------------------------------------------------------------------
def _work(n):
    sched = BucketScheduler(devices=None)
    return sched.buckets(list(range(n)))


@pytest.mark.parametrize("pipeline", [False, True])
def test_executor_results_in_bucket_order(pipeline):
    ex = PipelineExecutor(pipeline=pipeline)
    out = ex.run(
        _work(7),
        upload=lambda b: b.key * 10,
        dispatch=lambda b, staged: staged + 1,
    )
    assert out == [k * 10 + 1 for k in range(7)]
    assert ex.stats.buckets == 7


def test_executor_uploads_run_on_worker_and_dispatch_on_caller():
    ex = PipelineExecutor(pipeline=True, prefetch=2)
    upload_threads, dispatch_threads = set(), set()

    def upload(b):
        upload_threads.add(threading.current_thread().name)
        return b.key

    def dispatch(b, staged):
        dispatch_threads.add(threading.current_thread().name)
        return staged

    ex.run(_work(5), upload, dispatch)
    main = threading.current_thread().name
    assert dispatch_threads == {main}
    assert upload_threads and main not in upload_threads
    assert ex.stats.pipelined_buckets == 5


def test_executor_prefetch_bound():
    """The staging worker never runs more than `prefetch` buckets ahead of
    the last dispatched bucket."""
    ex = PipelineExecutor(pipeline=True, prefetch=2)
    state = {"uploaded": 0, "dispatched": 0}
    max_ahead = []

    def upload(b):
        state["uploaded"] += 1
        max_ahead.append(state["uploaded"] - state["dispatched"])
        return b.key

    def dispatch(b, staged):
        state["dispatched"] += 1
        return staged

    ex.run(_work(10), upload, dispatch)
    # upload k+prefetch may start only once bucket k dispatched (+1 for the
    # bucket currently between upload and dispatch)
    assert max(max_ahead) <= ex.prefetch + 1


def test_executor_single_bucket_stays_serial():
    ex = PipelineExecutor(pipeline=True)
    names = []
    ex.run(
        _work(1),
        upload=lambda b: names.append(threading.current_thread().name),
        dispatch=lambda b, staged: None,
    )
    assert names == [threading.current_thread().name]
    assert ex.stats.pipelined_buckets == 0


def test_executor_propagates_errors():
    ex = PipelineExecutor(pipeline=True)

    def upload(b):
        if b.key == 2:
            raise RuntimeError("stage boom")
        return b.key

    with pytest.raises(RuntimeError, match="stage boom"):
        ex.run(_work(4), upload, lambda b, s: s)
    # the executor stays usable after a failed run
    assert ex.run(_work(2), lambda b: b.key, lambda b, s: s) == [0, 1]


def test_executor_teardown_joins_inflight_upload():
    """Regression: a dispatch exception used to tear down via
    ``fut.cancel()`` alone — a no-op on an already-RUNNING future — so
    the staging worker's in-flight upload (possibly holding donated
    buffers) outlived run() and raced the next run() on the one-thread
    pool.  Teardown must JOIN the running upload before re-raising."""
    import time

    ex = PipelineExecutor(pipeline=True, prefetch=2)
    upload_started = threading.Event()
    uploads_done = []

    def upload(b):
        if b.key == 1:
            upload_started.set()
            time.sleep(0.3)  # long enough to be RUNNING at teardown
        uploads_done.append(b.key)
        return b.key

    def dispatch(b, staged):
        # fail bucket 0's dispatch only once bucket 1's upload is
        # mid-flight on the staging worker
        assert upload_started.wait(10)
        raise RuntimeError("dispatch boom")

    with pytest.raises(RuntimeError, match="dispatch boom"):
        ex.run(_work(4), upload, dispatch)
    # the in-flight upload was joined (completed), not abandoned
    assert 1 in uploads_done
    # the inflight gauge unwound: nothing leaked into the next run
    assert ex.inflight == 0
    assert ex.run(_work(2), lambda b: b.key, lambda b, s: s) == [0, 1]
    assert ex.inflight == 0


def test_executor_rejects_bad_prefetch():
    with pytest.raises(ValueError, match="prefetch"):
        PipelineExecutor(prefetch=0)


# ---------------------------------------------------------------------------
# Cross-engine byte identity: pipelined / sharded == synchronous.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tables():
    power = calibrate(
        make_signal("load_power", 65536, seed=7),
        DOMAIN_DEFAULTS["power"],
        domain_id=0,
    )
    meteo = calibrate(
        make_signal("temperature", 65536, seed=8),
        DOMAIN_DEFAULTS["meteorological"],
        domain_id=1,
    )
    return {0: power, 1: meteo}


@pytest.fixture(scope="module")
def archive(tables):
    sigs, doms = [], []
    for i, n in enumerate([2048, 1000, 3000, 257 * 8, 700, 4096]):
        dom = i % 2
        ds = "load_power" if dom == 0 else "temperature"
        sigs.append(make_signal(ds, n, seed=90 + i))
        doms.append(dom)
    containers = [
        encode(s, tables[d]) for s, d in zip(sigs, doms)
    ]
    return sigs, doms, containers


def _container_bytes(containers):
    return [c.to_bytes() for c in containers]


def test_pipelined_decode_byte_identical(tables, archive):
    _, _, containers = archive
    sync = BatchDecoder(pipeline=False, devices=None)
    pipe = BatchDecoder(pipeline=True, devices=None, prefetch=3)
    ref = sync.decode(containers, tables).to_host()
    got = pipe.decode(containers, tables).to_host()
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)
    assert pipe.executor.stats.pipelined_buckets >= 1


def test_pipelined_encode_byte_identical(tables, archive):
    sigs, doms, _ = archive
    sync = BatchEncoder(pipeline=False, devices=None, chunk_size=64)
    pipe = BatchEncoder(pipeline=True, devices=None, chunk_size=64)
    ref = sync.encode(sigs, tables, domain_ids=doms).to_host()
    got = pipe.encode(sigs, tables, domain_ids=doms).to_host()
    assert _container_bytes(got) == _container_bytes(ref)


def test_pipelined_transcode_byte_identical(tables, archive):
    _, _, containers = archive
    sync = Transcoder(pipeline=False, devices=None)
    pipe = Transcoder(pipeline=True, devices=None)
    ref = sync.transcode_to_host(containers, tables, tables[1],
                                 dst_domain_ids=[1] * len(containers))
    got = pipe.transcode_to_host(containers, tables, tables[1],
                                 dst_domain_ids=[1] * len(containers))
    assert _container_bytes(got) == _container_bytes(ref)


def test_sharded_engines_byte_identical(tables, archive):
    """Explicitly sharding over every visible device produces the same
    bytes as the single-device path (the real multi-shard split runs under
    the multi-device CI leg; with one device this pins the committed-
    placement path)."""
    sigs, doms, containers = archive
    devs = jax.local_devices()

    ref = BatchDecoder(devices=None).decode(containers, tables).to_host()
    got = BatchDecoder(devices=devs).decode(containers, tables).to_host()
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)

    ref = BatchEncoder(devices=None, chunk_size=128).encode(
        sigs, tables, domain_ids=doms
    ).to_host()
    enc = BatchEncoder(devices=devs, chunk_size=128)
    got = enc.encode(sigs, tables, domain_ids=doms).to_host()
    assert _container_bytes(got) == _container_bytes(ref)
    if len(devs) > 1:
        assert enc.stats.dispatches >= 2  # the batch axis actually split

    ref = Transcoder(devices=None).transcode_to_host(
        containers, tables, tables[0], dst_domain_ids=[0] * len(containers)
    )
    got = Transcoder(devices=devs).transcode_to_host(
        containers, tables, tables[0], dst_domain_ids=[0] * len(containers)
    )
    assert _container_bytes(got) == _container_bytes(ref)


def test_sharded_encoded_batch_transcode_byte_identical(tables, archive):
    """EncodedBatch-source transcode under explicit sharding: each shard's
    chunk parts stitch and re-encode on their own device, byte-identical
    to the single-device pipeline."""
    sigs, doms, _ = archive
    devs = jax.local_devices()

    def run(devices):
        batch = BatchEncoder(devices=devices, chunk_size=64).encode(
            sigs, tables, domain_ids=doms
        )
        return Transcoder(devices=devices, chunk_size=64).transcode_to_host(
            batch, tables, tables[1], dst_domain_ids=[1] * len(sigs)
        )

    assert _container_bytes(run(devs)) == _container_bytes(run(None))


def test_exact_capacity_transcode_byte_identical(tables, archive):
    """exact_capacity=True (one pre-decode sync on the true stitched word
    counts) changes decode-slot work only — output bytes are identical."""
    sigs, doms, _ = archive
    src_batch = BatchEncoder(chunk_size=32).encode(
        sigs, tables, domain_ids=doms
    )
    tc = Transcoder(chunk_size=32, exact_capacity=True)
    got = tc.transcode_to_host(
        src_batch, tables, tables[0], dst_domain_ids=[0] * len(sigs)
    )
    assert tc.stats.capacity_syncs == 1

    ref_batch = BatchEncoder(chunk_size=32).encode(
        sigs, tables, domain_ids=doms
    )
    ref = Transcoder(chunk_size=32).transcode_to_host(
        ref_batch, tables, tables[0], dst_domain_ids=[0] * len(sigs)
    )
    assert _container_bytes(got) == _container_bytes(ref)


def test_sharded_batch_into_narrower_transcoder(tables, archive):
    """Placement follows the data: an EncodedBatch sharded over every
    visible device feeds a SINGLE-device Transcoder — each shard's stream
    stitches, decodes and re-encodes on the device that holds it, and the
    bytes still match the unsharded pipeline.  (Regression: this used to
    index the transcoder's (None,) device tuple with the source's shard
    ids and crash under multi-device.)"""
    sigs, doms, _ = archive
    devs = jax.local_devices()
    batch = BatchEncoder(devices=devs, chunk_size=64).encode(
        sigs, tables, domain_ids=doms
    )
    got = Transcoder(devices=None, chunk_size=64).transcode_to_host(
        batch, tables, tables[1], dst_domain_ids=[1] * len(sigs)
    )
    ref_batch = BatchEncoder(devices=None, chunk_size=64).encode(
        sigs, tables, domain_ids=doms
    )
    ref = Transcoder(devices=None, chunk_size=64).transcode_to_host(
        ref_batch, tables, tables[1], dst_domain_ids=[1] * len(sigs)
    )
    assert _container_bytes(got) == _container_bytes(ref)


def test_fused_kernels_byte_identical(tables, archive):
    """use_kernels=True (the fused Pallas megakernel decode + fused encode
    tile, interpret mode on CPU) is byte-identical to the XLA stage
    definitions across all three engines — under however many devices are
    visible, so the 4-fake-device CI leg pins the sharded + pipelined
    kernel path too."""
    sigs, doms, containers = archive

    ref = BatchDecoder(use_kernels=False).decode(containers, tables)
    got = BatchDecoder(use_kernels=True).decode(containers, tables)
    for a, b in zip(got.to_host(), ref.to_host()):
        np.testing.assert_array_equal(a, b)

    ref = BatchEncoder(use_kernels=False, chunk_size=64).encode(
        sigs, tables, domain_ids=doms
    ).to_host()
    got = BatchEncoder(use_kernels=True, chunk_size=64).encode(
        sigs, tables, domain_ids=doms
    ).to_host()
    assert _container_bytes(got) == _container_bytes(ref)

    ref = Transcoder(use_kernels=False, chunk_size=64).transcode_to_host(
        containers, tables, tables[1], dst_domain_ids=[1] * len(containers)
    )
    got = Transcoder(use_kernels=True, chunk_size=64).transcode_to_host(
        containers, tables, tables[1], dst_domain_ids=[1] * len(containers)
    )
    assert _container_bytes(got) == _container_bytes(ref)

    # device-resident EncodedBatch source: stitch + megakernel decode +
    # fused re-encode, all kernels, still the same bytes
    src_k = BatchEncoder(use_kernels=True, chunk_size=64).encode(
        sigs, tables, domain_ids=doms
    )
    got = Transcoder(use_kernels=True, chunk_size=64).transcode_to_host(
        src_k, tables, tables[0], dst_domain_ids=[0] * len(sigs)
    )
    src_x = BatchEncoder(use_kernels=False, chunk_size=64).encode(
        sigs, tables, domain_ids=doms
    )
    ref = Transcoder(use_kernels=False, chunk_size=64).transcode_to_host(
        src_x, tables, tables[0], dst_domain_ids=[0] * len(sigs)
    )
    assert _container_bytes(got) == _container_bytes(ref)


def test_pinned_shard_without_device_mapping_raises():
    sched = BucketScheduler(devices=None)
    with pytest.raises(ValueError, match="shard_devices"):
        sched.buckets(["x", "x"], shard_ids=[0, 3])


def test_fused_gather_compile_bound(tables):
    """The fused gather+encode jit must specialize on BUCKETED shapes only:
    two archives with different raw sample totals that round to the same
    power-of-two flat length (and the same word/window buckets) reuse one
    XLA executable — an unbucketed flat length would recompile the whole
    DCT+quant+pack per archive size."""
    from repro.serving.batch_encode import _encode_bucket_gather

    try:
        _encode_bucket_gather._cache_size()
    except AttributeError:  # pragma: no cover - older/newer jax
        pytest.skip("jit cache size not exposed")

    def migrate(lengths, seed):
        containers = [
            encode(make_signal("load_power", n, seed=seed + i), tables[0])
            for i, n in enumerate(lengths)
        ]
        Transcoder(chunk_size=64).transcode_to_host(
            containers, tables[0], tables[1],
            dst_domain_ids=[1] * len(lengths),
        )

    migrate([3000, 1200], seed=300)
    size1 = _encode_bucket_gather._cache_size()
    migrate([2990, 1190], seed=310)  # different totals, same buckets
    assert _encode_bucket_gather._cache_size() == size1


def test_mismatched_transcoder_devices_raise(tables):
    with pytest.raises(ValueError, match="same devices"):
        Transcoder(
            decoder=BatchDecoder(devices=None),
            encoder=BatchEncoder(devices=jax.local_devices()),
        )


# ---------------------------------------------------------------------------
# Bucket policies: padding ladders change scheduling only, never bytes.
# ---------------------------------------------------------------------------
def test_bucket_policies_byte_identical(tables, archive):
    """All three bucket-edge ladders produce the same bytes: decoded
    samples always; encode/transcode streams in exact (unchunked) packing
    mode, where the word stream is independent of the bucket a signal
    landed in.  (Chunked packing legitimately varies with the window
    bucket — that contract is chunk padding, not policy.)"""
    sigs, doms, containers = archive
    ref = None
    for pol in POLICY_NAMES:
        dec = BatchDecoder(policy=pol)
        got_dec = [
            np.asarray(s) for s in dec.decode(containers, tables).to_host()
        ]
        assert dec.scheduler.policy.name == pol
        enc = BatchEncoder(policy=pol, chunk_size=None)
        got_enc = _container_bytes(
            enc.encode(sigs, tables, domain_ids=doms).to_host()
        )
        tc = Transcoder(policy=pol, chunk_size=None)
        got_tc = _container_bytes(
            tc.transcode_to_host(
                containers, tables, tables[1],
                dst_domain_ids=[1] * len(containers),
            )
        )
        if ref is None:
            ref = (got_dec, got_enc, got_tc)
            # exact-mode engine encode == the host reference codec
            assert got_enc == [
                encode(s, tables[d]).to_bytes()
                for s, d in zip(sigs, doms)
            ]
        else:
            for a, b in zip(got_dec, ref[0]):
                np.testing.assert_array_equal(a, b)
            assert got_enc == ref[1]
            assert got_tc == ref[2]


def test_mismatched_transcoder_policies_raise():
    with pytest.raises(ValueError, match="same bucket policy"):
        Transcoder(
            decoder=BatchDecoder(policy="p2"),
            encoder=BatchEncoder(policy="half-octave"),
        )


@pytest.mark.parametrize("pol", POLICY_NAMES)
def test_policy_compile_count_bounded(tables, pol):
    """Every policy's ladder keeps the fused-decode jit specializing on
    BUCKET edges only: archives with slightly different raw word/window
    totals that round to the same edges reuse the same executables, and a
    repeat of the same archive compiles nothing."""
    from repro.serving.batch_decode import bucket_cache_size

    if bucket_cache_size() is None:
        pytest.skip("jit cache size not exposed")

    def archive_of(lengths, seed):
        return [
            encode(make_signal("load_power", n, seed=seed + i), tables[0])
            for i, n in enumerate(lengths)
        ]

    dec = BatchDecoder(policy=pol)
    a1 = archive_of([3000, 1200, 5000], seed=500)
    dec.decode(a1, tables).to_host()
    size1 = bucket_cache_size()
    # nearby totals, same bucket edges under every ladder (seeds chosen so
    # the symlen bucket — a policy-independent static — matches too)
    # -> zero new compiles
    a2 = archive_of([2990, 1195, 4990], seed=520)
    dec.decode(a2, tables).to_host()
    assert bucket_cache_size() == size1
    dec.decode(a1, tables).to_host()
    assert bucket_cache_size() == size1


# ---------------------------------------------------------------------------
# Tuning cache: tuned kernel blocks retile dispatches, never change bytes.
# ---------------------------------------------------------------------------
def test_tuning_cache_warm_vs_cold_byte_identical(tables, archive, tmp_path):
    """Kernel-path engines under a COLD tuning cache (built-in block
    sizes) and again after the cache learns non-default blocks for the
    exact (plan key, bucket shape) entries the engines consult: the store
    bumps the epoch, the bucket jits retrace, the trace-time consult hits
    — and the bytes are identical."""
    from repro.serving.engine import symlen_bucket
    from repro.tuning import autotune

    sigs, doms, containers = archive
    backend = jax.default_backend()
    cache = autotune.TuningCache(str(tmp_path))
    autotune.set_default_cache(cache)
    try:
        dec = BatchDecoder(use_kernels=True)
        enc = BatchEncoder(use_kernels=True, chunk_size=64)
        cold_dec = [
            np.asarray(s) for s in dec.decode(containers, tables).to_host()
        ]
        cold_enc = _container_bytes(
            enc.encode(sigs, tables, domain_ids=doms).to_host()
        )

        # hand-tune non-default blocks under the EXACT keys the engines'
        # buckets consult at trace time
        e0 = autotune.epoch()
        groups = defaultdict(list)
        for c in containers:
            groups[c.plan_key].append(c)
        for key, cs in groups.items():
            c0 = cs[0]
            wp = dec.scheduler.round(sum(c.num_words for c in cs))
            winp = dec.scheduler.round(
                max(sum(c.num_windows for c in cs), 1)
            )
            ms = symlen_bucket(max(c.max_symlen for c in cs))
            cache.store(
                "decode", backend, (c0.n, c0.e, c0.l_max, ms), (wp, winp),
                {"block_words": 256, "block_windows": 128},
            )
        enc_groups = defaultdict(list)
        for s, d in zip(sigs, doms):
            cfg = tables[d].config
            nwin = -(-len(s) // cfg.n)
            wb = enc.scheduler.round(max(nwin, 1))
            enc_groups[(d, wb)].append(s)
        for (d, wb), members in enc_groups.items():
            cfg = tables[d].config
            sp = wb * cfg.e
            kp = enc.scheduler.round(len(members))
            cache.store(
                "encode", backend, (cfg.n, cfg.e, min(64, sp)),
                (kp, wb * cfg.n),
                {"block_rows": 3},  # pads the row axis inside the kernel
            )
        assert autotune.epoch() > e0

        hits0 = cache.hits
        warm_dec = [
            np.asarray(s) for s in dec.decode(containers, tables).to_host()
        ]
        warm_enc = _container_bytes(
            enc.encode(sigs, tables, domain_ids=doms).to_host()
        )
        # the consult actually HIT the stored entries (guards this test
        # against silently drifting out of sync with the ops.py keys)
        assert cache.hits > hits0

        for a, b in zip(warm_dec, cold_dec):
            np.testing.assert_array_equal(a, b)
        assert warm_enc == cold_enc
    finally:
        autotune.set_default_cache(None)


# ---------------------------------------------------------------------------
# Transfer guard: pipelining adds no d2h syncs before the drain.
# ---------------------------------------------------------------------------
def test_pipelining_adds_no_d2h_before_drain(tables, archive, monkeypatch):
    """Acceptance: with pipelining (and whatever sharding is visible) on,
    the decode -> re-encode pipeline performs ZERO device->host transfers
    before the explicit drain.  The jax transfer guard is set process-wide
    (the staging worker thread would escape a thread-local context
    manager); because same-platform CPU 'transfers' may not register with
    the guard, the drain entry points themselves are instrumented too —
    exactly one must run, at to_host()."""
    _, _, containers = archive
    drains = {"n": 0}
    real_fetch = batch_decode_mod.fetch_to_host
    real_stitched = batch_encode_mod.fetch_to_host_stitched

    def counting_fetch(arrays):
        drains["n"] += 1
        return real_fetch(arrays)

    def counting_stitched(bucket_arrays, stitch):
        drains["n"] += 1
        return real_stitched(bucket_arrays, stitch)

    monkeypatch.setattr(batch_decode_mod, "fetch_to_host", counting_fetch)
    monkeypatch.setattr(
        batch_encode_mod, "fetch_to_host_stitched", counting_stitched
    )

    tc = Transcoder(pipeline=True)
    jax.config.update("jax_transfer_guard_device_to_host", "disallow")
    try:
        out = tc.transcode(containers, tables, tables[1],
                           dst_domain_ids=[1] * len(containers))
        out.block_until_ready()  # device sync, not a transfer
        assert drains["n"] == 0
    finally:
        jax.config.update("jax_transfer_guard_device_to_host", None)
    migrated = out.to_host()
    assert drains["n"] == 1  # the single drain
    assert len(migrated) == len(containers)
