"""Data pipeline: determinism, sharding disjointness, generator stats."""
import numpy as np

from repro.data import SignalPipeline, TokenPipeline, make_signal
from repro.data.signals import DATASETS


def test_generators_deterministic():
    for name in DATASETS:
        a = make_signal(name, 2048, seed=5)
        b = make_signal(name, 2048, seed=5)
        np.testing.assert_array_equal(a, b)
        c = make_signal(name, 2048, seed=6)
        assert not np.array_equal(a, c)


def test_generator_shapes_and_finiteness():
    for name in DATASETS:
        x = make_signal(name, 4096, seed=0)
        assert x.shape == (4096,)
        assert x.dtype == np.float32
        assert np.all(np.isfinite(x))
        assert x.std() > 0


def test_signal_pipeline_host_sharding_disjoint():
    pipes = [
        SignalPipeline("mitbih", strip_length=1024, host_id=h, num_hosts=4)
        for h in range(4)
    ]
    strips = [p.strip(0) for p in pipes]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(strips[i], strips[j])


def test_token_pipeline_restartable():
    p = TokenPipeline(vocab_size=1000, batch_size=2, seq_len=16)
    t1, l1 = p.batch(7)
    t2, l2 = p.batch(7)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    assert t1.shape == (2, 16)
    assert np.all(t1 >= 0) and np.all(t1 < 1000)
    # labels are next-token shifted view of the same stream
    t3, _ = p.batch(8)
    assert not np.array_equal(t1, t3)


def test_token_pipeline_host_sharding():
    a = TokenPipeline(1000, 2, 16, host_id=0, num_hosts=2).batch(0)[0]
    b = TokenPipeline(1000, 2, 16, host_id=1, num_hosts=2).batch(0)[0]
    assert not np.array_equal(a, b)
