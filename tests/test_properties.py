"""Property-based round-trip suite (via the _hypothesis_compat shim).

Two families of properties, each with pinned regression cases that run
even without hypothesis installed (the @given variants skip through the
shim and execute for real on the CI leg that installs ``.[test]``):

  * ``encode -> transcode -> decode`` over drawn (signal length, n, e,
    l_max, chunk size): the transcoded container is byte-identical to the
    host round trip, and the re-quantization error it introduces is
    bounded by the target quantizer's zone cell widths.
  * ``pack_symlen_chunked`` output always unpacks — bit-exactly — under
    both the serial host decoder (``unpack_symlen_np``) and the Pallas
    ``huffman_decode_tile`` kernel (interpret mode).
  * drawn *mixed-domain batches* through the full serving pipeline —
    container-source AND device-resident ``EncodedBatch``-source transcode
    arms — are byte-identical to the engine round trip (decode to host,
    re-encode), signal order and routing preserved.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import decode, encode
from repro.core.calibration import DomainTables
from repro.core.config import CodecConfig
from repro.core.dct import forward_dct, window_signal
from repro.core.huffman import build_codebook
from repro.core.quantize import (
    build_quant_table,
    dequantize,
    quant_grid,
    quantize,
)
from repro.core.symlen import (
    PackedStream,
    compact_padded_scatter,
    pack_symlen_chunked,
    u32_to_words,
    unpack_symlen_np,
    words_to_u32,
)
from repro.serving import BatchDecoder, BatchEncoder, Transcoder


# ---------------------------------------------------------------------------
# Deterministic synthetic domains (no dataset dependence, fast to build).
# ---------------------------------------------------------------------------
def _walk(rng, length, scale=8.0):
    """A random-walk strip: smooth enough to compress, rough enough to
    populate many quantizer levels."""
    if length == 0:
        return np.empty(0, np.float32)
    return np.cumsum(
        rng.standard_normal(length).astype(np.float32)
    ) * np.float32(scale / max(length, 1) ** 0.5)


def _tables(seed, n, e, l_max, domain_id=0):
    """Calibration in miniature: quant table from a calibration walk's
    coefficient percentiles, codebook from its (Laplace-smoothed) symbol
    histogram — every uint8 symbol encodable, b2 == e so no zone-2 bins
    (whose 'cell width' is the whole coefficient range and would make the
    error-bound property vacuous)."""
    rng = np.random.default_rng(seed)
    calib = _walk(rng, 4096)
    coeffs = np.asarray(forward_dct(window_signal(jnp.asarray(calib), n), e))
    quant = build_quant_table(
        coeffs, b1=min(2, e), b2=e, mu=50.0, alpha1=0.004, percentile=99.5,
        scale_headroom=1.25,
    )
    syms = np.asarray(quantize(jnp.asarray(coeffs), quant)).ravel()
    hist = np.bincount(syms, minlength=256).astype(np.int64) + 1
    book = build_codebook(hist, l_max=l_max)
    cfg = CodecConfig(n=n, e=e, b1=min(2, e), b2=e, l_max=l_max)
    return DomainTables(
        config=cfg, quant=quant, book=book, domain_id=domain_id
    )


def _cell_width_bound(quant):
    """Per-bin upper bound on the reconstruction error of one quantize ->
    dequantize pass for in-range coefficients: the largest gap between
    adjacent reconstruction levels (midpoint reconstruction keeps every
    in-cell point within one level gap of its reconstruction)."""
    grid, _ = quant_grid(quant)
    grid = np.sort(np.asarray(grid), axis=1)  # [E, 256]
    return np.max(np.diff(grid, axis=1), axis=1)  # [E]


# ---------------------------------------------------------------------------
# Property 1: encode -> transcode -> decode.
# ---------------------------------------------------------------------------
def check_transcode_roundtrip(seed, length, n_src, e_src, l_max_src,
                              n_dst, e_dst, chunk_size):
    rng = np.random.default_rng(seed)
    src_tab = _tables(seed, n_src, e_src, l_max_src, domain_id=0)
    dst_tab = _tables(seed + 1, n_dst, e_dst, 12, domain_id=1)
    sig = _walk(rng, length)

    c_src = encode(sig, src_tab)
    tc = Transcoder(chunk_size=chunk_size)
    out = tc.transcode_to_host([c_src], src_tab, dst_tab)[0]

    # byte-identity vs the host round trip at the same chunk size
    src_rec = BatchDecoder().decode([c_src], src_tab).to_host()[0]
    ref = BatchEncoder(chunk_size=chunk_size).encode(
        [src_rec], dst_tab
    ).to_host()[0]
    assert out.to_bytes() == ref.to_bytes()

    # reconstruction error bound: re-quantizing the decoded source signal
    # under the target tables moves each retained coefficient by at most
    # one quantizer cell (plus any clip excess beyond the calibrated
    # scale)
    if length == 0:
        return
    coeffs = np.asarray(forward_dct(
        window_signal(jnp.asarray(src_rec), n_dst), e_dst
    ))  # [W, E] target-side coefficients of the signal that was re-encoded
    stream = PackedStream(
        words=out.words, symlen=out.symlen.astype(np.int32),
        num_symbols=out.num_symbols,
    )
    syms = unpack_symlen_np(stream, dst_tab.book)
    coeffs_hat = np.asarray(dequantize(
        jnp.asarray(syms.reshape(out.num_windows, e_dst)), dst_tab.quant
    ))
    err = np.abs(coeffs_hat - coeffs)
    scale = np.asarray(dst_tab.quant.scale)
    clip_excess = np.maximum(np.abs(coeffs) - scale[None, :], 0.0)
    bound = _cell_width_bound(dst_tab.quant)[None, :] * (1 + 1e-3) + (
        clip_excess + 1e-4
    )
    assert np.all(err <= bound), (
        f"requantization error {err.max()} exceeds zone cell bound at "
        f"{np.unravel_index(np.argmax(err - bound), err.shape)}"
    )

    # end to end: the transcoded container still decodes everywhere
    rec = decode(out, dst_tab)
    assert rec.shape == sig.shape


@pytest.mark.parametrize(
    "seed,length,n_src,e_src,l_max_src,n_dst,e_dst,chunk",
    [
        (0, 1000, 32, 8, 12, 16, 16, 64),
        (1, 257, 8, 4, 8, 32, 8, 7),
        (2, 2000, 16, 16, 16, 8, 2, 1024),
        (3, 5, 32, 6, 10, 8, 8, 33),
        (4, 0, 8, 8, 12, 16, 4, 16),  # empty signal
    ],
)
def test_transcode_roundtrip_pinned(seed, length, n_src, e_src, l_max_src,
                                    n_dst, e_dst, chunk):
    """Pinned draws of the property below — run with or without
    hypothesis."""
    check_transcode_roundtrip(
        seed, length, n_src, e_src, l_max_src, n_dst, e_dst, chunk
    )


@settings(max_examples=15, deadline=None)
@given(
    st.integers(0, 2**16),
    st.integers(0, 2000),
    st.sampled_from([8, 16, 32]),
    st.sampled_from([2, 4, 8]),
    st.sampled_from([8, 12, 16]),
    st.sampled_from([8, 16, 32]),
    st.sampled_from([2, 4, 8]),
    st.integers(1, 300),
)
def test_transcode_roundtrip_property(seed, length, n_src, e_div, l_max_src,
                                      n_dst, e_div_dst, chunk):
    # e drawn as a divisor of n so every (n, e) pairing is valid
    check_transcode_roundtrip(
        seed, length, n_src, max(n_src // e_div, 1), l_max_src,
        n_dst, max(n_dst // e_div_dst, 1), chunk,
    )


# ---------------------------------------------------------------------------
# Property 2: chunked pack -> (serial | Pallas-interpret) unpack.
# ---------------------------------------------------------------------------
def check_chunked_pack_unpacks_everywhere(seed, num_symbols, chunk_size,
                                          l_max):
    rng = np.random.default_rng(seed)
    raw = rng.zipf(1.3, max(num_symbols, 1))[:num_symbols]
    syms = np.clip(raw, 0, 255).astype(np.uint8)
    freqs = np.bincount(syms, minlength=256).astype(np.int64) + 1
    book = build_codebook(freqs, l_max=l_max)

    hi, lo, sl, nw = pack_symlen_chunked(
        jnp.asarray(syms),
        jnp.asarray(book.codes, jnp.uint32),
        jnp.asarray(book.lengths, jnp.int32),
        chunk_size=chunk_size,
    )
    nw = int(nw)
    hi, lo = np.asarray(hi[:nw]), np.asarray(lo[:nw])
    sl = np.asarray(sl[:nw])

    # serial host decoder
    stream = PackedStream(
        words=u32_to_words(hi, lo), symlen=sl, num_symbols=syms.size
    )
    np.testing.assert_array_equal(unpack_symlen_np(stream, book), syms)

    # Pallas kernel (interpret mode), slot-major tile + scatter compaction
    if nw == 0:
        return
    from repro.kernels.huffman_decode import (
        huffman_decode_dense,
        huffman_decode_tile,
    )

    max_symlen = int(sl.max()) if sl.size else 0
    tile = huffman_decode_tile(
        jnp.asarray(hi), jnp.asarray(lo),
        jnp.asarray(book.limit_shifted[1:], jnp.uint32),
        jnp.asarray(book.first_code_shifted, jnp.uint32),
        jnp.asarray(book.rank_offset, jnp.int32),
        jnp.asarray(book.sorted_symbols, jnp.int32),
        l_max=book.l_max,
        max_symlen=max(max_symlen, 1),
        block_words=64,
        interpret=True,
    )
    got = compact_padded_scatter(
        tile.T, jnp.asarray(sl), int(syms.size)
    )
    np.testing.assert_array_equal(
        np.asarray(got).astype(np.uint8), syms
    )

    # fused dense kernel: in-kernel prefix-scan compaction, one dispatch
    dense = huffman_decode_dense(
        jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(sl),
        jnp.asarray(book.limit_shifted[1:], jnp.uint32),
        jnp.asarray(book.first_code_shifted, jnp.uint32),
        jnp.asarray(book.rank_offset, jnp.int32),
        jnp.asarray(book.sorted_symbols, jnp.int32),
        l_max=book.l_max,
        max_symlen=max(max_symlen, 1),
        num_symbols=int(syms.size),
        block_words=64,
        interpret=True,
    )
    np.testing.assert_array_equal(
        np.asarray(dense).astype(np.uint8), syms
    )


@pytest.mark.parametrize(
    "seed,num_symbols,chunk,l_max",
    [
        (10, 2000, 64, 12),
        (11, 63, 7, 8),
        (12, 4096, 1024, 16),
        (13, 1, 1, 9),
        (14, 500, 501, 10),  # single chunk larger than the stream
    ],
)
def test_chunked_pack_unpacks_everywhere_pinned(seed, num_symbols, chunk,
                                                l_max):
    check_chunked_pack_unpacks_everywhere(seed, num_symbols, chunk, l_max)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(0, 2**16),
    st.integers(1, 3000),
    st.integers(1, 600),
    st.integers(8, 16),
)
def test_chunked_pack_unpacks_everywhere_property(seed, num_symbols, chunk,
                                                  l_max):
    check_chunked_pack_unpacks_everywhere(seed, num_symbols, chunk, l_max)


# ---------------------------------------------------------------------------
# Property 3: drawn mixed-domain batches, container- and EncodedBatch-source.
# ---------------------------------------------------------------------------
def check_mixed_domain_batch(seed, specs, chunk_size, from_encoded):
    """``specs`` is [(length, domain)] per signal.  The whole serving
    pipeline on a mixed-domain batch — batched encode, then transcode from
    either drained containers or the device-resident EncodedBatch — must
    be byte-identical to the engine round trip (decode to host signals,
    re-encode under the target tables), order and domain routing
    preserved."""
    rng = np.random.default_rng(seed)
    # two source domains with distinct (n, e, l_max) operating points, one
    # target config: fixed shapes keep XLA bucket compiles bounded while
    # the drawn lengths sweep window/batch bucket boundaries
    src = {
        0: _tables(seed, 16, 4, 12, domain_id=0),
        1: _tables(seed + 1, 8, 4, 10, domain_id=1),
    }
    dst = _tables(seed + 2, 32, 8, 12, domain_id=2)
    lengths = [length for length, _ in specs]
    doms = [dom for _, dom in specs]
    sigs = [_walk(rng, length) for length in lengths]

    batch = BatchEncoder(chunk_size=chunk_size).encode(
        sigs, src, domain_ids=doms
    )
    if from_encoded:
        # reference containers from an identically-configured second encode
        # (the batch itself is consumed by the transcode)
        ref_containers = BatchEncoder(chunk_size=chunk_size).encode(
            sigs, src, domain_ids=doms
        ).to_host()
        source = batch
    else:
        ref_containers = batch.to_host()
        source = ref_containers

    ref_sigs = BatchDecoder().decode(ref_containers, src).to_host()
    ref = BatchEncoder(chunk_size=chunk_size).encode(ref_sigs, dst).to_host()
    got = Transcoder(chunk_size=chunk_size).transcode_to_host(
        source, src, dst
    )
    assert len(got) == len(ref) == len(sigs)
    for a, b in zip(got, ref):
        assert a.to_bytes() == b.to_bytes()
        assert a.domain_id == dst.domain_id
    # transcoded containers still decode to the source order's shapes
    for c, sig in zip(got, sigs):
        assert decode(c, dst).shape == sig.shape


@pytest.mark.parametrize(
    "seed,specs,chunk,from_encoded",
    [
        (20, [(1000, 0), (257, 1), (0, 0), (129, 1)], 64, False),
        (21, [(513, 1), (512, 0), (511, 1)], 33, True),
        (22, [(2000, 0)], 1024, True),  # single-signal degenerate draw
        (23, [(5, 1), (700, 1), (700, 0), (64, 0), (63, 1)], 7, False),
    ],
)
def test_mixed_domain_batch_pinned(seed, specs, chunk, from_encoded):
    """Pinned draws of the mixed-domain property — run with or without
    hypothesis."""
    check_mixed_domain_batch(seed, specs, chunk, from_encoded)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 2**16),
    st.lists(
        st.tuples(st.integers(0, 1200), st.integers(0, 1)),
        min_size=1, max_size=6,
    ),
    st.sampled_from([16, 64, 1024]),
    st.booleans(),
)
def test_mixed_domain_batch_property(seed, specs, chunk, from_encoded):
    check_mixed_domain_batch(seed, specs, chunk, from_encoded)


# ---------------------------------------------------------------------------
# Property 4: fixed-rate KV domain — size is a pure function of the shape,
# and the per-coefficient error obeys the quantizer's zone cell widths.
# ---------------------------------------------------------------------------
def check_kv_fixed_rate(seed, b, w, h, d):
    from repro.core.domains import calibrate_kv
    from repro.serving.workloads import KVCacheCodec

    rng = np.random.default_rng(seed)
    cfg_kv = None  # domain default: n == e (quantization-only)
    codec = KVCacheCodec(config=cfg_kv)
    n = codec.config.n
    t = w * n
    # smooth token timeline per (b, h, d) channel: walk along axis 1
    kv = np.cumsum(
        rng.standard_normal((b, t, h, d)).astype(np.float32), axis=1
    ) * np.float32(4.0 / t ** 0.5)
    tables = codec.calibrate(kv)

    ckv = codec.compress(kv)
    e = codec.config.e
    assert ckv.levels.dtype == jnp.uint8
    assert ckv.levels.shape == (b, h, d, w, e)
    assert ckv.nbytes == b * h * d * w * e  # fixed size, no sidecar
    rec = codec.decompress(ckv)
    assert rec.shape == kv.shape and rec.dtype == kv.dtype

    # error bound: every retained coefficient moved by at most one
    # quantizer cell (plus clip excess beyond the calibrated scale)
    strips = np.moveaxis(kv, 1, -1).reshape(-1, t)
    coeffs = np.asarray(forward_dct(
        window_signal(jnp.asarray(strips), n), e
    ))  # [C, W, E]
    coeffs_hat = np.asarray(dequantize(
        jnp.asarray(np.asarray(ckv.levels).reshape(-1, w, e)), tables.quant
    ))
    err = np.abs(coeffs_hat - coeffs)
    scale = np.asarray(tables.quant.scale)
    clip_excess = np.maximum(np.abs(coeffs) - scale[None, None, :], 0.0)
    bound = _cell_width_bound(tables.quant)[None, None, :] * (1 + 1e-3) + (
        clip_excess + 1e-4
    )
    assert np.all(err <= bound)


@pytest.mark.parametrize(
    "seed,b,w,h,d",
    [
        (30, 2, 4, 4, 8),
        (31, 1, 1, 1, 1),  # single window, single channel
        (32, 3, 2, 2, 4),
    ],
)
def test_kv_fixed_rate_pinned(seed, b, w, h, d):
    check_kv_fixed_rate(seed, b, w, h, d)


@settings(max_examples=8, deadline=None)
@given(
    st.integers(0, 2**16),
    st.integers(1, 3),
    st.integers(1, 4),
    st.integers(1, 4),
    st.sampled_from([1, 4, 8]),
)
def test_kv_fixed_rate_property(seed, b, w, h, d):
    check_kv_fixed_rate(seed, b, w, h, d)


# ---------------------------------------------------------------------------
# Property 5: train-state sharding — shard/unshard is exact for any leaf
# mix, and the batched container path is byte-identical to the per-shard
# core encode.
# ---------------------------------------------------------------------------
def check_train_state_shards(seed, sizes, shard_len):
    from repro.core.domains import calibrate_train_state
    from repro.serving.workloads import (
        shard_state,
        state_from_containers,
        state_to_containers,
        unshard_state,
    )

    rng = np.random.default_rng(seed)
    arrays = {
        f"leaf{i}": _walk(rng, size, scale=4.0)
        for i, size in enumerate(sizes)
    }
    shards, manifest = shard_state(arrays, shard_len=shard_len)
    assert all(s.size <= shard_len for s in shards)
    back = unshard_state(shards, manifest)
    for k, a in arrays.items():
        np.testing.assert_array_equal(back[k], a)

    tables = calibrate_train_state(arrays)
    containers, manifest2 = state_to_containers(
        arrays, tables, shard_len=shard_len
    )
    assert len(containers) == len(shards)
    # byte-identity shard by shard vs a ONE-signal engine encode of the
    # normalized shards: batching the whole checkpoint must not change a
    # single container byte (the serial core encoder packs without chunk
    # flushes, so its word stream is only comparable at matching chunk
    # sizes — the engine is the byte-level reference here, the core
    # decoder the value-level one)
    norm_shards, _ = shard_state(
        arrays, shard_len=shard_len, normalize=True
    )
    ref_enc = BatchEncoder()
    for cont, shard in zip(containers, norm_shards):
        assert cont.to_bytes() == ref_enc.encode(
            [shard], tables
        ).to_host()[0].to_bytes()
    rec = state_from_containers(containers, manifest2, tables)
    for k, a in arrays.items():
        assert rec[k].shape == a.shape and rec[k].dtype == a.dtype
        # shard boundaries land on window boundaries (shard_len % n == 0),
        # so the sharded path must reproduce the whole-leaf reference
        # round trip (same per-leaf unit-max-abs normalization) to float
        # tolerance
        amax = float(np.max(np.abs(a))) if a.size else 0.0
        scale = amax if amax > 0.0 else 1.0
        ref = (
            decode(encode(a / np.float32(scale), tables), tables) * scale
            if a.size else a
        )
        np.testing.assert_allclose(
            rec[k], np.asarray(ref, np.float32), rtol=0,
            atol=1e-6 * scale, err_msg=k,
        )


@pytest.mark.parametrize(
    "seed,sizes,shard_len",
    [
        (40, [1000, 64, 4097], 4096),
        (41, [1], 64),
        (42, [4096, 4096], 4096),  # exact multiples: no tail shards
        (43, [0, 300], 128),  # empty leaf rides along
    ],
)
def test_train_state_shards_pinned(seed, sizes, shard_len):
    check_train_state_shards(seed, sizes, shard_len)


@settings(max_examples=8, deadline=None)
@given(
    st.integers(0, 2**16),
    st.lists(st.integers(0, 3000), min_size=1, max_size=4),
    st.sampled_from([64, 512, 4096]),
)
def test_train_state_shards_property(seed, sizes, shard_len):
    check_train_state_shards(seed, sizes, shard_len)
