"""Sharding policy, gradient compression, and multi-device train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import CompressionConfig, GradCompressor
from repro.distributed.sharding import DEFAULT_RULES, ShardingPolicy
from repro.models.common import ParamSpec


class FakeMesh:
    """Axis-size stand-in for spec resolution tests (no devices needed)."""

    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np

        self.devices = _np.empty(tuple(sizes.values()), dtype=object)


def test_policy_divisibility_fallback():
    policy = ShardingPolicy(FakeMesh({"data": 16, "model": 16}))
    # 20 heads on a 16-way model axis -> replicated
    spec = policy.spec_for(("hidden", "heads", None), (2560, 20, 128))
    assert spec == jax.sharding.PartitionSpec("data")
    # 32 heads -> sharded
    spec = policy.spec_for(("hidden", "heads", None), (4096, 32, 128))
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_policy_no_axis_reuse():
    policy = ShardingPolicy(FakeMesh({"data": 4, "model": 4}))
    # both dims want "model": only the first gets it
    spec = policy.spec_for(("seq", "ffn"), (64, 64))
    assert spec == jax.sharding.PartitionSpec("model")


def test_policy_exclude():
    mesh = FakeMesh({"pod": 2, "data": 8, "model": 16})
    full = ShardingPolicy(mesh)
    nopod = full.without("pod")
    s_full = full.spec_for(("hidden",), (4096,))
    s_nopod = nopod.spec_for(("hidden",), (4096,))
    assert s_full == jax.sharding.PartitionSpec(("pod", "data"))
    # spec_for unwraps single-axis entries; newer JAX no longer treats
    # P(("data",)) and P("data") as equal, so compare the canonical form
    assert s_nopod == jax.sharding.PartitionSpec("data")
    assert nopod.fsdp_axes == ("data",)


def test_compressor_spectrum_roundtrip_smooth():
    """Smooth gradients survive DCT truncation nearly unchanged."""
    comp = GradCompressor(CompressionConfig(mode="truncate", n=64, e=32))
    t = np.linspace(0, 20, 8192)
    g = jnp.asarray(np.sin(t) + 0.3 * np.sin(3 * t), jnp.float32)
    spec, size = comp._to_spectrum(g)
    back = comp._from_spectrum(spec, size, g.shape, g.dtype)
    rel = float(jnp.linalg.norm(back - g) / jnp.linalg.norm(g))
    assert rel < 0.05


def test_compressor_wire_bytes_accounting():
    comp = GradCompressor(CompressionConfig(mode="truncate_int8", n=64, e=16))
    n = 64 * 1000
    assert comp.wire_bytes(n) == 1000 * 16  # int8 * E per window
    assert comp.wire_bytes(n) / (n * 4) == pytest.approx(1 / 16.0)


def test_error_feedback_recovers_quantization_error():
    """EF fully recovers the (state-dependent) int8 quantization error:
    the mean applied update converges to the true gradient when the only
    lossy stage is quantization (n == e: no truncation)."""
    n = 32
    rng = np.random.default_rng(0)
    g_true = np.random.default_rng(0).standard_normal(2048).astype(np.float32)
    g_true = jnp.asarray(g_true)

    from repro.core import dct as dctlib

    residual = jnp.zeros_like(g_true)
    applied = jnp.zeros_like(g_true)
    steps = 40
    for _ in range(steps):
        g_eff = g_true + residual
        spec = dctlib.forward_dct(g_eff.reshape(-1, n), n)
        scale = (jnp.max(jnp.abs(spec)) + 1e-12) / 127.0
        q = jnp.clip(jnp.round(spec / scale), -127, 127)
        g_hat = dctlib.inverse_dct(q * scale, n).reshape(-1)
        residual = 0.9 * (g_eff - g_hat)
        applied = applied + g_hat
    rel = float(
        jnp.linalg.norm(applied / steps - g_true) / jnp.linalg.norm(g_true)
    )
    one_shot = dctlib.inverse_dct(
        jnp.round(
            dctlib.forward_dct(g_true.reshape(-1, n), n) / scale
        ) * scale, n,
    ).reshape(-1)
    one_rel = float(jnp.linalg.norm(one_shot - g_true) / jnp.linalg.norm(g_true))
    assert rel < one_rel * 0.7  # EF beats one-shot quantization
    assert rel < 0.01


def test_truncation_is_fixed_projection_and_residual_bounded():
    """Spectral truncation is a FIXED projection: the applied update equals
    the projected gradient (the orthogonal part is permanently filtered —
    the smooth-gradient prior), and the decayed residual stays bounded."""
    comp = GradCompressor(CompressionConfig(mode="truncate", n=32, e=8,
                                            ef_decay=0.9))
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(2048), jnp.float32)

    spec, size = comp._to_spectrum(g_true)
    proj = comp._from_spectrum(spec, size, g_true.shape, jnp.float32)

    residual = jnp.zeros_like(g_true)
    norms = []
    applied = jnp.zeros_like(g_true)
    for _ in range(50):
        g_eff = g_true + residual
        s, _ = comp._to_spectrum(g_eff)
        g_hat = comp._from_spectrum(s, size, g_true.shape, jnp.float32)
        residual = 0.9 * (g_eff - g_hat)
        applied = applied + g_hat
        norms.append(float(jnp.linalg.norm(residual)))
    # applied/k == projection of g (orthogonal part never passes the wire)
    rel = float(jnp.linalg.norm(applied / 50 - proj) / jnp.linalg.norm(proj))
    assert rel < 1e-4
    # residual converges to the geometric limit beta/(1-beta)*|(I-P)g| —
    # bounded, not linear growth (without decay it grows without bound)
    orth = float(jnp.linalg.norm(g_true - proj))
    assert norms[-1] <= 9.0 * orth * 1.05
    assert norms[-1] - norms[-5] < 0.02 * norms[-1]  # plateaued


def test_train_step_single_device_mesh():
    """make_train_step end to end on a 1x1 mesh: loss decreases."""
    from repro.configs import get_smoke
    from repro.distributed.optimizer import AdamW, AdamWConfig
    from repro.distributed.train import make_train_step
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.models.common import init_params

    cfg = get_smoke("granite_8b")
    model = build_model(cfg)
    mesh = make_local_mesh(1, 1)
    opt = AdamW(AdamWConfig(base_lr=3e-3, warmup=2, total_steps=40))
    ts = make_train_step(model, opt, mesh)
    rng = np.random.default_rng(0)
    with mesh:
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        state = opt.init(params)
        # one repeated batch: loss must drop (memorization)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        losses = []
        for _ in range(15):
            params, state, metrics = ts.step_fn(params, state, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_moe_sort_rank():
    from repro.models.moe_distributed import sort_rank

    e = jnp.asarray([2, 0, 2, 1, 2, 0], jnp.int32)
    rank = np.asarray(sort_rank(e, 3))
    np.testing.assert_array_equal(rank, [0, 0, 1, 0, 2, 1])


def test_validate_mesh_for_catches_indivisible():
    from repro.distributed.elastic import validate_mesh_for

    policy = ShardingPolicy(FakeMesh({"data": 3, "model": 5}))
    specs = {"w": ParamSpec((16, 10), ("hidden", "ffn"))}
    problems = validate_mesh_for(policy, specs)
    # 16 % 3 != 0 -> hidden won't shard (replicated, fine); 10 % 5 == 0 ->
    # ffn shards cleanly; no problems expected
    assert problems == []
    # force a bad rule: dim sharded but indivisible can't happen through
    # spec_for (divisibility-checked), so validate passes by construction
    specs2 = {"w": ParamSpec((15, 64), ("hidden", "ffn"))}
    assert validate_mesh_for(policy, specs2) == []
