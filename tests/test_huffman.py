"""Length-limited canonical Huffman: optimality, invariants, decode tables."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.huffman import (
    build_codebook,
    decode_prefix_arith,
    kraft_sum,
    package_merge_lengths,
)


def test_kraft_equality_simple():
    freqs = np.zeros(256, np.int64)
    freqs[:8] = [100, 50, 25, 12, 6, 3, 2, 1]
    lengths = package_merge_lengths(freqs, 12)
    assert abs(kraft_sum(lengths) - 1.0) < 1e-12


def test_single_symbol():
    freqs = np.zeros(256, np.int64)
    freqs[42] = 10
    book = build_codebook(freqs, l_max=8)
    assert book.lengths[42] == 1
    assert book.num_active == 1


def test_length_limit_enforced():
    # pathological exponential distribution would want lengths > 6
    freqs = np.zeros(256, np.int64)
    freqs[:32] = [2 ** i for i in range(32)]
    book = build_codebook(freqs, l_max=6)
    active = book.lengths[book.lengths > 0]
    assert active.max() <= 6
    assert abs(kraft_sum(book.lengths) - 1.0) < 1e-12


def test_matches_entropy_bound():
    rng = np.random.default_rng(0)
    freqs = rng.integers(1, 10_000, 256).astype(np.int64)
    book = build_codebook(freqs, l_max=16)
    p = freqs / freqs.sum()
    entropy = -(p * np.log2(p)).sum()
    avg = book.expected_bits(freqs)
    assert entropy <= avg <= entropy + 1.0  # Huffman redundancy bound


def test_prefix_free():
    rng = np.random.default_rng(1)
    freqs = rng.integers(0, 1000, 256).astype(np.int64)
    freqs[freqs < 10] = 0
    book = build_codebook(freqs, l_max=12)
    codes = [
        (format(book.codes[s], "b").zfill(book.lengths[s]))
        for s in range(256)
        if book.lengths[s] > 0
    ]
    for i, a in enumerate(codes):
        for j, b in enumerate(codes):
            if i != j:
                assert not b.startswith(a) or len(b) < len(a)


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.integers(0, 5000), min_size=256, max_size=256),
    st.integers(9, 14),
)
def test_property_valid_codebook(freq_list, l_max):
    freqs = np.asarray(freq_list, np.int64)
    if freqs.sum() == 0:
        freqs[0] = 1
    book = build_codebook(freqs, l_max=l_max)
    active = book.lengths > 0
    # every symbol with nonzero freq has a code
    assert np.all(active[freqs > 0])
    if active.sum() > 1:
        assert abs(kraft_sum(book.lengths) - 1.0) < 1e-9
    assert book.lengths.max() <= l_max


def test_lut_vs_arithmetic_decode_agree():
    rng = np.random.default_rng(2)
    freqs = rng.integers(1, 500, 256).astype(np.int64)
    book = build_codebook(freqs, l_max=12)
    prefixes = rng.integers(0, 1 << 12, 4096).astype(np.uint32)
    sym_a, len_a = decode_prefix_arith(book, prefixes)
    sym_l = book.lut_symbol[prefixes]
    len_l = book.lut_length[prefixes]
    np.testing.assert_array_equal(sym_a, sym_l)
    np.testing.assert_array_equal(len_a, len_l)
