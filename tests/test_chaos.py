"""The chaos soak: an open-loop multi-thousand-request replay with seeded
payload corruption and dispatcher sabotage, asserting the full fault-
isolation contract at once —

  * **zero silent drops** — every submitted request resolves to exactly
    one typed outcome (the :class:`ChaosReport` accounting is closed);
  * **zero hangs** — no future outlives the replay, even when a dispatch
    hangs outright (the watchdog cuts it loose with a typed error);
  * **typed poison** — every corrupted container surfaces as
    :class:`PoisonedContainerError` (or its admission-time
    ``ContainerFormatError`` twin), never as a batch-wide failure;
  * **byte identity** — every clean request's result equals the offline
    engines' output bit for bit, corruption and retries notwithstanding.

The sharded leg re-runs a soak over auto-sharded pipelined engines and
is exercised by the multidevice CI job
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""
import jax
import numpy as np
import pytest

from repro.core import DOMAIN_DEFAULTS, calibrate
from repro.data import make_signal
from repro.serving.batch_decode import BatchDecoder
from repro.serving.frontend import (
    FrontendConfig,
    RetryPolicy,
    ServingFrontend,
)
from repro.serving.traffic import DOMAIN_DATASETS, TrafficConfig, generate
from repro.testing.faults import (
    CONTAINER_FAULTS,
    DispatcherFaultInjector,
    chaos_replay,
    offline_expected,
)

CHAOS_SEED = 1303


@pytest.fixture(scope="module")
def chaos_tables():
    """Two serving domains with *different* codec configs (power e=6,
    meteorological e=8) so a flipped domain_id deterministically lands on
    plan-mismatch, not a silent wrong-tables decode."""
    tables = {}
    for domain_id in (2, 3):
        domain, dataset = DOMAIN_DATASETS[domain_id]
        tables[domain_id] = calibrate(
            make_signal(dataset, 32768, seed=1000 + domain_id),
            DOMAIN_DEFAULTS[domain],
            domain_id=domain_id,
        )
    return tables


# ---------------------------------------------------------------------------
# The soak.
# ---------------------------------------------------------------------------
def test_chaos_soak_typed_outcomes_and_byte_identity(chaos_tables):
    """>=2k mixed requests, >=5% of container traffic corrupted cycling
    every fault class, transient dispatch faults + device loss + latency
    injected mid-stream: clean results byte-identical to offline, poison
    typed per-request, the accounting closed, and the retry policy
    absorbing every transient (zero dispatch failures surface)."""
    cfg = TrafficConfig(
        rate=2400.0, duration_s=1.0, fixed_windows=8,
        mix={"decode": 0.5, "encode": 0.3, "transcode": 0.2},
        domains=(2, 3), seed=CHAOS_SEED,
    )
    requests = generate(cfg, chaos_tables)
    assert len(requests) >= 2000, "soak needs a >=2k-request stream"
    expected = offline_expected(requests, chaos_tables)

    inj = DispatcherFaultInjector(
        fail_on={3, 11}, latency_on={6: 0.05}, device_loss_on={17},
    )
    fcfg = FrontendConfig(
        max_batch=64, max_queue_depth=4096, default_slo_ms=600_000.0,
        retry=RetryPolicy(max_retries=2, base_backoff_ms=1.0),
    )
    with ServingFrontend(
        chaos_tables, config=fcfg, pipeline=True, devices=None,
        fault_injector=inj,
    ) as fe:
        report = chaos_replay(
            fe, requests, corrupt_frac=0.06, seed=CHAOS_SEED,
            expected=expected, result_timeout_s=600.0,
        )
        stats = fe.stats_snapshot()

    # the chaos actually happened: corruption covered every fault class,
    # and the dispatcher took >=3 injected faults
    corruptible = sum(r.kind != "encode" for r in requests)
    assert report.corrupted >= max(
        len(CONTAINER_FAULTS), int(0.05 * corruptible)
    )
    assert len(inj.injected) >= 3

    # zero silent drops, zero hangs, zero untyped failures
    assert report.accounted == report.total == len(requests)
    assert report.hangs == 0
    assert report.untyped_failures == 0

    # every corrupted request surfaced as typed poison; every clean one
    # completed byte-identical to the offline engines
    assert report.poisoned == report.corrupted
    assert report.clean_ok == report.clean
    assert report.clean_mismatches == 0
    assert report.dispatch_failed == 0  # retries absorbed every transient

    assert stats.retries >= 3
    assert stats.retry_successes >= 3
    # poison splits between admission (header-visible faults typed at
    # submit, never admitted) and engine staging (payload faults counted
    # by the frontend's quarantine); together they cover every corruption
    admission_poison = report.total - stats.admitted
    assert stats.quarantined + admission_poison == report.corrupted
    assert stats.quarantined > 0 and admission_poison > 0


def test_chaos_hung_dispatch_resolves_typed_not_hung(chaos_tables):
    """A dispatch that hangs outright: the watchdog cuts it loose, its
    members resolve with a *typed* DispatchFailedError (a hang would be
    the one forbidden outcome), and the replacement dispatcher finishes
    the rest of the stream."""
    cfg = TrafficConfig(
        rate=200.0, duration_s=0.5, fixed_windows=8,
        mix={"decode": 1.0}, domains=(2,), seed=CHAOS_SEED + 1,
    )
    requests = generate(cfg, chaos_tables)
    assert len(requests) >= 20
    expected = offline_expected(requests, chaos_tables)

    inj = DispatcherFaultInjector(hang_on={2}, hang_timeout_s=120.0)
    fcfg = FrontendConfig(
        max_batch=8, max_queue_depth=4096, default_slo_ms=600_000.0,
        retry=RetryPolicy(max_retries=1, base_backoff_ms=1.0),
        watchdog_timeout_ms=500.0, watchdog_poll_ms=25.0,
    )
    try:
        with ServingFrontend(
            chaos_tables, config=fcfg, pipeline=False, devices=None,
            fault_injector=inj,
        ) as fe:
            report = chaos_replay(
                fe, requests, corrupt_frac=0.0, seed=CHAOS_SEED + 1,
                expected=expected, result_timeout_s=600.0,
            )
            stats = fe.stats_snapshot()
            health = fe.health()
    finally:
        inj.release()  # unblock the abandoned dispatcher before exiting

    assert report.accounted == report.total
    assert report.hangs == 0
    assert report.untyped_failures == 0
    assert report.clean_mismatches == 0
    # the hung batch's members failed TYPED; everything else completed
    assert report.dispatch_failed > 0
    assert report.ok + report.dispatch_failed == report.total
    assert stats.watchdog_restarts == 1
    assert health["status"] == "degraded"
    assert any(kind == "hang" for _, kind in inj.injected)


@pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >1 device (CI multidevice leg: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)
def test_chaos_soak_sharded_multidevice(chaos_tables):
    """The soak over auto-sharded pipelined engines: quarantine excludes
    poison *before* the shard split, so clean batch-mates stay
    byte-identical to the offline single-device engines even while
    corrupt requests and transient dispatch faults land mid-stream."""
    cfg = TrafficConfig(
        rate=600.0, duration_s=0.5, fixed_windows=8,
        mix={"decode": 0.6, "encode": 0.4}, domains=(2,),
        seed=CHAOS_SEED + 2,
    )
    requests = generate(cfg, chaos_tables)
    assert len(requests) >= 100
    expected = offline_expected(requests, chaos_tables)

    inj = DispatcherFaultInjector(fail_on={2})
    fcfg = FrontendConfig(
        max_batch=32, max_queue_depth=4096, default_slo_ms=600_000.0,
        retry=RetryPolicy(max_retries=2, base_backoff_ms=1.0),
    )
    with ServingFrontend(
        chaos_tables, config=fcfg, pipeline=True, devices="auto",
        fault_injector=inj,
    ) as fe:
        report = chaos_replay(
            fe, requests, corrupt_frac=0.1, seed=CHAOS_SEED + 2,
            expected=expected, result_timeout_s=600.0,
        )

    assert report.accounted == report.total
    assert report.hangs == 0
    assert report.untyped_failures == 0
    assert report.poisoned == report.corrupted > 0
    assert report.clean_ok == report.clean
    assert report.clean_mismatches == 0
    assert inj.injected  # the transient fault fired and was absorbed


# ---------------------------------------------------------------------------
# Harness units.
# ---------------------------------------------------------------------------
def test_chaos_replay_is_deterministic_in_seed(chaos_tables):
    """Which requests get corrupted, and with which fault, depends only
    on (stream, corrupt_frac, seed) — a chaos failure is reproducible
    from its seed alone."""
    cfg = TrafficConfig(
        rate=120.0, duration_s=0.5, fixed_windows=4,
        mix={"decode": 1.0}, domains=(2,), seed=CHAOS_SEED + 3,
    )
    requests = generate(cfg, chaos_tables)

    def outcomes():
        with ServingFrontend(
            chaos_tables,
            config=FrontendConfig(
                max_batch=16, max_queue_depth=4096,
                default_slo_ms=600_000.0,
            ),
            pipeline=False, devices=None,
        ) as fe:
            rep = chaos_replay(
                fe, requests, corrupt_frac=0.2, seed=CHAOS_SEED + 3,
                result_timeout_s=600.0,
            )
        return [(i, kind) for i, kind, _ in rep.outcomes]

    assert outcomes() == outcomes()


def test_chaos_report_accounting_identity():
    from repro.testing.faults import ChaosReport

    rep = ChaosReport(
        total=10, ok=4, poisoned=3, dispatch_failed=1, rejected=1,
        untyped_failures=1, hangs=0,
    )
    assert rep.accounted == 10


def test_offline_oracle_matches_traffic_payloads(chaos_tables):
    """generate() pre-encodes decode payloads byte-identically to the
    offline encoder — the oracle and the stream agree on what 'clean'
    means before any chaos runs."""
    cfg = TrafficConfig(
        rate=60.0, duration_s=0.5, fixed_windows=4,
        mix={"decode": 1.0}, domains=(2,), seed=CHAOS_SEED + 4,
    )
    requests = generate(cfg, chaos_tables)
    expected = offline_expected(requests, chaos_tables)
    for i, r in enumerate(requests):
        dec = BatchDecoder(pipeline=False, devices=None)
        out = dec.decode([r.container], chaos_tables[r.domain_id]).to_host()
        np.testing.assert_array_equal(out[0], expected[i])
