"""SymLen bitstream: Algorithm 1 fidelity + parallel decode equivalence."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.huffman import build_codebook
from repro.core.symlen import (
    PackedStream,
    pack_symlen_chunked,
    pack_symlen_np,
    pack_symlen_scan,
    u32_to_words,
    unpack_symlen,
    unpack_symlen_np,
    words_to_u32,
)


def _book(seed=0, l_max=12):
    rng = np.random.default_rng(seed)
    freqs = rng.integers(1, 1000, 256).astype(np.int64)
    return build_codebook(freqs, l_max=l_max)


def _decode_args(book):
    return dict(
        dec_limit=jnp.asarray(book.limit_shifted[1:], jnp.uint32),
        dec_first=jnp.asarray(book.first_code_shifted, jnp.uint32),
        dec_rank=jnp.asarray(book.rank_offset, jnp.int32),
        dec_syms=jnp.asarray(book.sorted_symbols, jnp.int32),
    )


def test_roundtrip_np():
    book = _book()
    rng = np.random.default_rng(3)
    syms = rng.integers(0, 256, 10_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    out = unpack_symlen_np(stream, book)
    np.testing.assert_array_equal(out, syms)


def test_scan_encoder_bit_identical_to_alg1():
    book = _book(1)
    rng = np.random.default_rng(4)
    syms = rng.integers(0, 256, 5_000).astype(np.uint8)
    ref = pack_symlen_np(syms, book)
    hi, lo, sl, nw = pack_symlen_scan(
        jnp.asarray(syms),
        jnp.asarray(book.codes, jnp.uint32),
        jnp.asarray(book.lengths, jnp.int32),
    )
    nw = int(nw)
    words = u32_to_words(np.asarray(hi[:nw]), np.asarray(lo[:nw]))
    np.testing.assert_array_equal(words, ref.words)
    np.testing.assert_array_equal(np.asarray(sl[:nw]), ref.symlen)


def test_parallel_decode_matches_serial():
    book = _book(2)
    rng = np.random.default_rng(5)
    syms = rng.integers(0, 256, 20_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    hi, lo = words_to_u32(stream.words)
    out = unpack_symlen(
        jnp.asarray(hi), jnp.asarray(lo),
        jnp.asarray(stream.symlen, jnp.int32),
        l_max=book.l_max,
        max_symlen=stream.max_symlen,
        num_symbols=stream.num_symbols,
        **_decode_args(book),
    )
    np.testing.assert_array_equal(np.asarray(out), syms)


def test_word_independence():
    """Every word decodes correctly in isolation — the SymLen property that
    makes the GPU/TPU decoder synchronization-free."""
    book = _book(6)
    rng = np.random.default_rng(7)
    syms = rng.integers(0, 256, 4_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    # decode words one at a time, in reverse order; concatenation must equal
    # the original stream
    pieces = []
    for w in reversed(range(stream.num_words)):
        sub = PackedStream(
            words=stream.words[w : w + 1],
            symlen=stream.symlen[w : w + 1],
            num_symbols=int(stream.symlen[w]),
        )
        pieces.append(unpack_symlen_np(sub, book))
    out = np.concatenate(pieces[::-1])
    np.testing.assert_array_equal(out, syms)


def test_codewords_never_split():
    """No codeword straddles a 64-bit boundary: total bits per word <= 64."""
    book = _book(8)
    rng = np.random.default_rng(9)
    syms = rng.integers(0, 256, 8_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    pos = 0
    for sl in stream.symlen:
        bits = sum(int(book.lengths[s]) for s in syms[pos : pos + sl])
        assert bits <= 64
        pos += sl
    assert pos == syms.size


def _enc_args(book):
    return (
        jnp.asarray(book.codes, jnp.uint32),
        jnp.asarray(book.lengths, jnp.int32),
    )


@pytest.mark.parametrize("chunk_size", [7, 64, 333, 1024, 20_000])
def test_chunked_decodes_bit_exactly_with_bounded_padding(chunk_size):
    """Tentpole acceptance: the chunk-parallel packer's output decodes
    bit-exactly on the UNCHANGED serial decoder, and chunk-boundary padding
    costs < 1 word per chunk vs the sequential packer."""
    book = _book(10)
    rng = np.random.default_rng(11)
    syms = rng.integers(0, 256, 10_000).astype(np.uint8)
    ref = pack_symlen_np(syms, book)
    hi, lo, sl, nw = pack_symlen_chunked(
        jnp.asarray(syms), *_enc_args(book), chunk_size=chunk_size
    )
    nw = int(nw)
    words = u32_to_words(np.asarray(hi[:nw]), np.asarray(lo[:nw]))
    stream = PackedStream(
        words=words, symlen=np.asarray(sl[:nw]), num_symbols=syms.size
    )
    np.testing.assert_array_equal(unpack_symlen_np(stream, book), syms)
    num_chunks = -(-syms.size // chunk_size)
    assert nw - ref.num_words < num_chunks  # < 1 padding word per chunk
    # per-word validity: symlen counts must sum to the symbol count and no
    # word may exceed 64 bits
    assert int(np.asarray(sl[:nw]).sum()) == syms.size
    pos = 0
    for s in np.asarray(sl[:nw]):
        assert sum(int(book.lengths[x]) for x in syms[pos:pos + s]) <= 64
        pos += s


def test_chunked_single_chunk_bit_identical_to_alg1():
    book = _book(12)
    rng = np.random.default_rng(13)
    syms = rng.integers(0, 256, 3_000).astype(np.uint8)
    ref = pack_symlen_np(syms, book)
    hi, lo, sl, nw = pack_symlen_chunked(
        jnp.asarray(syms), *_enc_args(book), chunk_size=syms.size
    )
    nw = int(nw)
    assert nw == ref.num_words
    words = u32_to_words(np.asarray(hi[:nw]), np.asarray(lo[:nw]))
    np.testing.assert_array_equal(words, ref.words)
    np.testing.assert_array_equal(np.asarray(sl[:nw]), ref.symlen)


def test_chunked_num_symbols_mask_ignores_padding():
    """Symbols past num_symbols are stacking padding: they must pack to
    nothing, so bucketed batch encoding can't corrupt streams."""
    book = _book(14)
    rng = np.random.default_rng(15)
    syms = rng.integers(0, 256, 2_000).astype(np.uint8)
    padded = np.concatenate([syms, rng.integers(0, 256, 741).astype(np.uint8)])
    hi, lo, sl, nw = pack_symlen_chunked(
        jnp.asarray(padded), *_enc_args(book), chunk_size=256,
        num_symbols=syms.size,
    )
    hi2, lo2, sl2, nw2 = pack_symlen_chunked(
        jnp.asarray(syms), *_enc_args(book), chunk_size=256
    )
    nw = int(nw)
    assert nw == int(nw2)
    np.testing.assert_array_equal(np.asarray(hi[:nw]), np.asarray(hi2[:nw]))
    np.testing.assert_array_equal(np.asarray(lo[:nw]), np.asarray(lo2[:nw]))
    np.testing.assert_array_equal(np.asarray(sl[:nw]), np.asarray(sl2[:nw]))


def test_all_pack_paths_reject_histogram_gap():
    """Satellite bugfix: a symbol with lengths[sym] == 0 used to pack to
    zero bits on the device paths while still counting in symlen — silent
    garbage.  All three packers must now reject the same input."""
    freqs = np.random.default_rng(16).integers(1, 1000, 256).astype(np.int64)
    freqs[17] = 0  # histogram gap
    book = build_codebook(freqs, l_max=12)
    assert int(book.lengths[17]) == 0
    bad = np.array([1, 17, 3], dtype=np.uint8)
    with pytest.raises(ValueError, match="no codeword"):
        pack_symlen_np(bad, book)
    with pytest.raises(ValueError, match="no codeword"):
        pack_symlen_scan(jnp.asarray(bad), *_enc_args(book))
    with pytest.raises(ValueError, match="no codeword"):
        pack_symlen_chunked(jnp.asarray(bad), *_enc_args(book), chunk_size=2)
    # the same symbols under a gap-free book pack fine on every path
    ok_book = _book(16)
    pack_symlen_np(bad, ok_book)
    pack_symlen_scan(jnp.asarray(bad), *_enc_args(ok_book))
    pack_symlen_chunked(jnp.asarray(bad), *_enc_args(ok_book), chunk_size=2)


def test_chunked_rejects_bad_chunk_size():
    book = _book(17)
    with pytest.raises(ValueError, match="chunk_size"):
        pack_symlen_chunked(
            jnp.zeros(8, jnp.uint8), *_enc_args(book), chunk_size=0
        )


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 2000))
def test_property_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    # skewed distribution (zipf-ish) to exercise variable lengths
    raw = rng.zipf(1.3, n)
    syms = np.clip(raw, 0, 255).astype(np.uint8)
    freqs = np.bincount(syms, minlength=256).astype(np.int64) + 1
    book = build_codebook(freqs, l_max=12)
    stream = pack_symlen_np(syms, book)
    out = unpack_symlen_np(stream, book)
    np.testing.assert_array_equal(out, syms)
    # parallel path agrees
    hi, lo = words_to_u32(stream.words)
    out2 = unpack_symlen(
        jnp.asarray(hi), jnp.asarray(lo),
        jnp.asarray(stream.symlen, jnp.int32),
        l_max=book.l_max, max_symlen=stream.max_symlen,
        num_symbols=stream.num_symbols, **_decode_args(book),
    )
    np.testing.assert_array_equal(np.asarray(out2), syms)
    # chunk-parallel packer stays decoder-compatible at an arbitrary chunk
    chunk = 1 + seed % 257
    chi, clo, csl, cnw = pack_symlen_chunked(
        jnp.asarray(syms),
        jnp.asarray(book.codes, jnp.uint32),
        jnp.asarray(book.lengths, jnp.int32),
        chunk_size=chunk,
    )
    cnw = int(cnw)
    cstream = PackedStream(
        words=u32_to_words(np.asarray(chi[:cnw]), np.asarray(clo[:cnw])),
        symlen=np.asarray(csl[:cnw]),
        num_symbols=syms.size,
    )
    np.testing.assert_array_equal(unpack_symlen_np(cstream, book), syms)
    assert cnw - stream.num_words < -(-syms.size // chunk)
