"""SymLen bitstream: Algorithm 1 fidelity + parallel decode equivalence."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.huffman import build_codebook
from repro.core.symlen import (
    PackedStream,
    pack_symlen_np,
    pack_symlen_scan,
    u32_to_words,
    unpack_symlen,
    unpack_symlen_np,
    words_to_u32,
)


def _book(seed=0, l_max=12):
    rng = np.random.default_rng(seed)
    freqs = rng.integers(1, 1000, 256).astype(np.int64)
    return build_codebook(freqs, l_max=l_max)


def _decode_args(book):
    return dict(
        dec_limit=jnp.asarray(book.limit_shifted[1:], jnp.uint32),
        dec_first=jnp.asarray(book.first_code_shifted, jnp.uint32),
        dec_rank=jnp.asarray(book.rank_offset, jnp.int32),
        dec_syms=jnp.asarray(book.sorted_symbols, jnp.int32),
    )


def test_roundtrip_np():
    book = _book()
    rng = np.random.default_rng(3)
    syms = rng.integers(0, 256, 10_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    out = unpack_symlen_np(stream, book)
    np.testing.assert_array_equal(out, syms)


def test_scan_encoder_bit_identical_to_alg1():
    book = _book(1)
    rng = np.random.default_rng(4)
    syms = rng.integers(0, 256, 5_000).astype(np.uint8)
    ref = pack_symlen_np(syms, book)
    hi, lo, sl, nw = pack_symlen_scan(
        jnp.asarray(syms),
        jnp.asarray(book.codes, jnp.uint32),
        jnp.asarray(book.lengths, jnp.int32),
    )
    nw = int(nw)
    words = u32_to_words(np.asarray(hi[:nw]), np.asarray(lo[:nw]))
    np.testing.assert_array_equal(words, ref.words)
    np.testing.assert_array_equal(np.asarray(sl[:nw]), ref.symlen)


def test_parallel_decode_matches_serial():
    book = _book(2)
    rng = np.random.default_rng(5)
    syms = rng.integers(0, 256, 20_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    hi, lo = words_to_u32(stream.words)
    out = unpack_symlen(
        jnp.asarray(hi), jnp.asarray(lo),
        jnp.asarray(stream.symlen, jnp.int32),
        l_max=book.l_max,
        max_symlen=stream.max_symlen,
        num_symbols=stream.num_symbols,
        **_decode_args(book),
    )
    np.testing.assert_array_equal(np.asarray(out), syms)


def test_word_independence():
    """Every word decodes correctly in isolation — the SymLen property that
    makes the GPU/TPU decoder synchronization-free."""
    book = _book(6)
    rng = np.random.default_rng(7)
    syms = rng.integers(0, 256, 4_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    # decode words one at a time, in reverse order; concatenation must equal
    # the original stream
    pieces = []
    for w in reversed(range(stream.num_words)):
        sub = PackedStream(
            words=stream.words[w : w + 1],
            symlen=stream.symlen[w : w + 1],
            num_symbols=int(stream.symlen[w]),
        )
        pieces.append(unpack_symlen_np(sub, book))
    out = np.concatenate(pieces[::-1])
    np.testing.assert_array_equal(out, syms)


def test_codewords_never_split():
    """No codeword straddles a 64-bit boundary: total bits per word <= 64."""
    book = _book(8)
    rng = np.random.default_rng(9)
    syms = rng.integers(0, 256, 8_000).astype(np.uint8)
    stream = pack_symlen_np(syms, book)
    pos = 0
    for sl in stream.symlen:
        bits = sum(int(book.lengths[s]) for s in syms[pos : pos + sl])
        assert bits <= 64
        pos += sl
    assert pos == syms.size


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 2000))
def test_property_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    # skewed distribution (zipf-ish) to exercise variable lengths
    raw = rng.zipf(1.3, n)
    syms = np.clip(raw, 0, 255).astype(np.uint8)
    freqs = np.bincount(syms, minlength=256).astype(np.int64) + 1
    book = build_codebook(freqs, l_max=12)
    stream = pack_symlen_np(syms, book)
    out = unpack_symlen_np(stream, book)
    np.testing.assert_array_equal(out, syms)
    # parallel path agrees
    hi, lo = words_to_u32(stream.words)
    out2 = unpack_symlen(
        jnp.asarray(hi), jnp.asarray(lo),
        jnp.asarray(stream.symlen, jnp.int32),
        l_max=book.l_max, max_symlen=stream.max_symlen,
        num_symbols=stream.num_symbols, **_decode_args(book),
    )
    np.testing.assert_array_equal(np.asarray(out2), syms)
