"""Per-architecture smoke tests: reduced configs, one fwd/train step on CPU.

The FULL assigned configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) — per the assignment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_arch, get_smoke
from repro.models import build_model
from repro.models.common import init_params


def _batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32
        ),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.full(
            (b, cfg.vision_prefix, cfg.d_model), 0.01, jnp.bfloat16
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.full(
            (b, cfg.encoder_seq, cfg.d_model), 0.01, jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_shapes(arch_id):
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step_no_nans(arch_id):
    from repro.distributed.optimizer import AdamW, AdamWConfig

    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(1))
    opt = AdamW(AdamWConfig(base_lr=1e-3, warmup=1, total_steps=10))
    state = opt.init(params)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    new_params, new_state, gnorm = opt.update(params, state, grads)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.isfinite(gnorm))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(
            jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))
        ), f"{arch_id}: NaN/inf in updated params"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch_id):
    """Greedy token from prefill == greedy token from loss-path logits."""
    cfg = get_smoke(arch_id)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(2))
    batch = _batch(cfg, b=2, s=12)
    logits, cache = model.prefill(params, batch, max_len=24)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = model.decode_step(params, cache, tok, jnp.int32(12))
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_assigned_configs_match_spec():
    """Exact dims from the assignment table."""
    expect = {
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "gemma2_27b": (46, 4608, 32, 16, 36864, 256000),
        "qwen15_4b": (40, 2560, 20, 20, 6912, 151936),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "deepseek_v3_671b": (61, 7168, 128, 128, 18432, 129280),
        "internvl2_26b": (48, 6144, 48, 8, 16384, 92553),
        "hymba_15b": (32, 1600, 25, 5, 5504, 32001),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
    }
    for aid, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_arch(aid)
        assert cfg.num_layers == L, aid
        assert cfg.d_model == d, aid
        assert cfg.num_heads == h, aid
        assert cfg.num_kv_heads == kv, aid
        assert cfg.d_ff == ff, aid
        assert cfg.vocab_size == v, aid
    # family-specific extras
    ds = get_arch("deepseek_v3_671b")
    assert ds.moe_num_experts == 256 and ds.moe_top_k == 8 and ds.mla
    assert ds.moe_d_ff == 2048
    l4 = get_arch("llama4_scout_17b_a16e")
    assert l4.moe_num_experts == 16 and l4.moe_top_k == 1
    hy = get_arch("hymba_15b")
    assert hy.ssm_state == 16 and hy.hybrid_parallel
    g2 = get_arch("gemma2_27b")
    assert g2.local_global_pattern == ("local", "global")


def test_cell_grid_is_40_with_documented_skips():
    grid = cells()
    assert len(grid) == 40
    skipped = [c for c in grid if c.skip]
    # long_500k skipped for the 8 non-sub-quadratic archs
    assert len(skipped) == 8
    for c in skipped:
        assert c.shape.name == "long_500k"
        assert c.arch_id not in ("rwkv6_3b", "hymba_15b")


def test_param_count_sanity():
    """param_count() within 15% of the published sizes."""
    approx = {
        "granite_8b": 8.1e9,
        "qwen15_4b": 3.9e9,
        "gemma2_27b": 27.2e9,
        "deepseek_v3_671b": 671e9,
        "rwkv6_3b": 3.1e9,
    }
    for aid, expect in approx.items():
        got = get_arch(aid).param_count()
        assert abs(got - expect) / expect < 0.30, (
            f"{aid}: param_count {got/1e9:.2f}B vs expected {expect/1e9:.1f}B"
        )
