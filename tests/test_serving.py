"""Serving-layer tests: KV-cache compression fidelity + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (
    KVCompressionConfig,
    compress_kv_block,
    decompress_kv_block,
)


@pytest.mark.parametrize("n,e", [(8, 4), (16, 8), (16, 16)])
def test_kv_roundtrip_error(n, e):
    rng = np.random.default_rng(0)
    # smooth-ish KV timeline (adjacent tokens correlated, like trained models)
    base = np.cumsum(rng.standard_normal((2, 64, 4, 32)) * 0.2, axis=1)
    kv = jnp.asarray(base, jnp.bfloat16)
    cfg = KVCompressionConfig(n=n, e=e)
    levels, scale = compress_kv_block(kv, cfg)
    rec = decompress_kv_block(levels, scale, cfg)
    rel = float(
        jnp.linalg.norm((rec - kv).astype(jnp.float32))
        / jnp.linalg.norm(kv.astype(jnp.float32))
    )
    if e == n:
        assert rel < 0.02  # quantization-only error
    else:
        assert rel < 0.25


def test_kv_compression_saves_memory():
    cfg = KVCompressionConfig(n=16, e=8)
    kv = jnp.zeros((1, 64, 4, 32), jnp.bfloat16)
    levels, scale = compress_kv_block(kv, cfg)
    raw = kv.size * 2
    comp = levels.size + scale.size * 4
    assert comp < raw * 0.7


def test_decode_with_quantized_cache_logit_drift():
    """Quantization-only KV compression (n == e) must barely move decode
    logits.  (A random-init model's argmax is near-uniform, so top-1
    agreement is not a stable metric — logit drift is.)"""
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.models.common import init_params

    cfg = get_smoke("granite_8b")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32)
    }
    logits, cache = model.prefill(params, batch, max_len=S + 4)
    kcfg = KVCompressionConfig(n=16, e=16)  # quantization only
    new_cache = {}
    for g, grp in cache.items():
        ng = dict(grp)
        for key in ("k", "v"):
            kv = grp[key]
            outs = []
            for l in range(kv.shape[0]):
                block = kv[l][:, :S]
                lv, sc = compress_kv_block(block, kcfg)
                rec = decompress_kv_block(lv, sc, kcfg, dtype=kv.dtype)
                outs.append(jnp.zeros_like(kv[l]).at[:, :S].set(rec))
            ng[key] = jnp.stack(outs)
        new_cache[g] = ng
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg_ref, _ = model.decode_step(params, cache, tok, jnp.int32(S))
    lg_cmp, _ = model.decode_step(params, new_cache, tok, jnp.int32(S))
    ref = lg_ref.astype(jnp.float32)
    cmp_ = lg_cmp.astype(jnp.float32)
    drift = float(jnp.linalg.norm(ref - cmp_) / (jnp.linalg.norm(ref) + 1e-9))
    assert drift < 0.15, f"quantization-only KV cache moved logits {drift}"
