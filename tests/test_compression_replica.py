"""replica_sum (the vmap'd compressed-DP reduction) — numerical contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import CompressionConfig, GradCompressor


def _grads(p=2, n=4096, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((p, n)), jnp.float32) * 0.01,
        "b": jnp.asarray(rng.standard_normal((p, 64)), jnp.float32),  # small
    }


def test_mode_none_is_plain_mean():
    comp = GradCompressor(CompressionConfig(mode="none"))
    g = _grads()
    out, _ = comp.replica_sum(g, None)
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.asarray(jnp.mean(g["w"], 0)), rtol=1e-6
    )


def test_small_leaves_bypass_compression():
    comp = GradCompressor(CompressionConfig(mode="truncate_int8", min_size=4096))
    g = _grads()
    out, _ = comp.replica_sum(g, None)
    # "b" (64 elems) bypasses: exact mean
    np.testing.assert_allclose(
        np.asarray(out["b"]), np.asarray(jnp.mean(g["b"], 0)), rtol=1e-6
    )


def test_int8_quantization_error_bounded():
    comp = GradCompressor(
        CompressionConfig(mode="truncate_int8", n=64, e=64)  # quant only
    )
    g = _grads()
    out, _ = comp.replica_sum(g, None)
    ref = np.asarray(jnp.mean(g["w"], 0))
    got = np.asarray(out["w"])
    rel = np.linalg.norm(got - ref) / np.linalg.norm(ref)
    assert rel < 0.02, rel  # int8 of the spectrum: ~1% error


def test_truncation_equals_projected_mean():
    cfg = CompressionConfig(mode="truncate", n=32, e=8)
    comp = GradCompressor(cfg)
    g = _grads()
    out, _ = comp.replica_sum(g, None)
    # reference: project the mean through the same DCT truncation
    mean = jnp.mean(g["w"], 0)
    spec, size = comp._to_spectrum(mean)
    proj = comp._from_spectrum(
        spec.astype(jnp.bfloat16), size, mean.shape, jnp.float32
    )
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.asarray(proj), atol=2e-4
    )


def test_residual_tracks_filtered_mass_and_decays():
    cfg = CompressionConfig(mode="truncate", n=32, e=8, ef_decay=0.9)
    comp = GradCompressor(cfg)
    g = _grads()
    r0 = {k: jnp.zeros_like(v, jnp.bfloat16) for k, v in g.items()}
    out, r1 = comp.replica_sum(g, r0)
    # residual is nonzero exactly where compression was lossy
    assert float(jnp.abs(r1["w"].astype(jnp.float32)).max()) > 0
    # and scaled by ef_decay: |r1| <= 0.9 * |g_filtered| <= 0.9 * |g|
    assert float(jnp.linalg.norm(r1["w"].astype(jnp.float32))) <= (
        0.91 * float(jnp.linalg.norm(g["w"]))
    )


def test_wire_ratio_property():
    for n, e in ((64, 32), (64, 16), (32, 8)):
        cfg = CompressionConfig(mode="truncate_int8", n=n, e=e)
        comp = GradCompressor(cfg)
        elems = n * 1000
        assert comp.wire_bytes(elems) == 1000 * e
        assert cfg.ratio == pytest.approx((e / n) / 4.0)
