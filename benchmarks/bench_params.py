"""Tables 1 & 2: codec parameter table + dataset inventory."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import DOMAIN_DEFAULTS
from repro.data.signals import DATASETS, domain_of


def run(fast: bool = False):
    del fast
    for dom, cfg in sorted(DOMAIN_DEFAULTS.items()):
        emit(
            f"params/{dom}", 0.0,
            f"N={cfg.n} E={cfg.e} B1={cfg.b1} B2={cfg.b2} mu={cfg.mu} "
            f"alpha1={cfg.alpha1} pct={cfg.a0_percentile} "
            f"headroom={cfg.scale_headroom} Lmax={cfg.l_max}",
        )
    for ds in sorted(DATASETS):
        emit(f"datasets/{ds}", 0.0, f"domain={domain_of(ds)} synthetic=1")


if __name__ == "__main__":
    run()
