"""Fig. 12 + Table 3: decompression throughput by PRD bin + trial stability.

Measures the word-parallel decode pipeline (jitted XLA path — the TPU
kernels run interpret=True on CPU and are validated for correctness, not
speed).  Throughput is decompressed-output GB/s, excluding host transfer —
the paper's measurement convention.  CPU numbers are not TPU numbers; the
roofline section projects the TPU-side bound.  Five sequential trials on a
warmed jit replicate Table 3's stability protocol.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_signal, tables_for
from repro.core import DOMAIN_DEFAULTS, encode
from repro.core.codec import _decode_device
from repro.core.config import CodecConfig
from repro.core.metrics import prd
from repro.core import symlen as symlib
from repro.data.signals import DATASETS, domain_of

ART = "benchmarks/artifacts/throughput"

PRD_BINS = ((0.0, 2.0), (2.0, 4.0), (4.0, 6.0))


def decode_gbps(container, tables, trials=5):
    hi, lo = symlib.words_to_u32(container.words)
    hi = jnp.asarray(hi)
    lo = jnp.asarray(lo)
    sl = jnp.asarray(container.symlen, jnp.int32)
    dev = tables.device_tables()
    kw = dict(
        l_max=container.l_max, max_symlen=container.max_symlen,
        num_symbols=container.num_symbols, num_windows=container.num_windows,
        n=container.n, e=container.e, signal_length=container.signal_length,
    )
    out = _decode_device(hi, lo, sl, dev, **kw)  # warm the jit
    out.block_until_ready()
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        out = _decode_device(hi, lo, sl, dev, **kw)
        out.block_until_ready()
        times.append(time.perf_counter() - t0)
    out_bytes = container.signal_length * 4
    return [out_bytes / t / 1e9 for t in times]


def run(fast: bool = False):
    os.makedirs(ART, exist_ok=True)
    datasets = ["mitbih", "load_power", "wind_speed"] if fast else sorted(
        DATASETS
    )
    results = {}
    for ds in datasets:
        dom = domain_of(ds)
        base = DOMAIN_DEFAULTS[dom]
        sig = eval_signal(ds, 1 << 20)  # 4 MB strips
        per_bin = {}
        for n, e in [(32, max(base.e // 2, 1)), (32, base.e),
                     (32, min(base.e * 2, 32))]:
            cfg = CodecConfig(
                n=n, e=e, b1=min(base.b1, e), b2=e, mu=base.mu,
                alpha1=base.alpha1, a0_percentile=base.a0_percentile,
                scale_headroom=base.scale_headroom,
            )
            tables = tables_for(ds, cfg)
            c = encode(sig, tables)
            from repro.core.codec import decode as hdecode

            p = prd(sig, hdecode(c, tables))
            gbps = decode_gbps(c, tables)
            for lo_b, hi_b in PRD_BINS:
                if lo_b <= p < hi_b:
                    key = f"({lo_b:.0f},{hi_b:.0f}]"
                    if key not in per_bin or np.mean(gbps) > np.mean(
                        per_bin[key]["gbps"]
                    ):
                        per_bin[key] = {
                            "prd": p, "cr": c.compression_ratio,
                            "gbps": gbps, "e": e, "n": n,
                        }
        results[ds] = per_bin
        for key, rec in per_bin.items():
            emit(
                f"throughput/{ds}/prd{key}",
                1e6 * (1 << 22) / (np.mean(rec["gbps"]) * 1e9),
                f"GBps_mean={np.mean(rec['gbps']):.3f} "
                f"GBps_min={np.min(rec['gbps']):.3f} CR={rec['cr']:.1f} "
                f"PRD={rec['prd']:.2f}",
            )
    with open(os.path.join(ART, "throughput.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    run()
