"""Fig. 12 + Table 3: decompression throughput by PRD bin + trial stability,
plus the batched serving measurements (containers/sec + GB/s at batch sizes
1/8/64) that the BatchDecoder and BatchEncoder engines exist for.

Measures the word-parallel decode pipeline (jitted XLA path — the TPU
kernels run interpret=True on CPU and are validated for correctness, not
speed).  Throughput is decompressed-output GB/s, excluding host transfer —
the paper's measurement convention.  CPU numbers are not TPU numbers; the
roofline section projects the TPU-side bound.  Five sequential trials on a
warmed jit replicate Table 3's stability protocol.

The batched section compares two ways to drain the same archive:

  * **per-container loop** — the legacy ``_decode_device`` jit whose static
    argnames (num_symbols, num_windows, signal_length, ...) force one XLA
    specialization per distinct container shape, plus per-call dispatch and
    host sync;
  * **BatchDecoder** — concatenated streams, power-of-two shape buckets, one
    fused dispatch per (domain, config) group, outputs drained once.

Both are reported warm (steady state) and cold (including compile), so the
speedup is measured, not asserted.

The encode-side section mirrors it for ingest/transcoding:

  * **per-signal loop** — the legacy ``_encode_stages_device`` jit: a
    length-S serial packing scan, one XLA specialization per signal length,
    and a blocking ``int(num_words)`` host sync per container;
  * **BatchEncoder** — chunk-parallel packing (``pack_symlen_chunked``),
    power-of-two shape buckets, one fused DCT+quant+pack dispatch per
    bucket, streams drained once.  The chunk-padding CR loss (<1 word per
    chunk, by construction) is reported alongside the speedup.

The transcode section (``--mode transcode``) measures the archive-migration
path the Transcoder exists for:

  * **container round trip** — BatchDecoder drain to host signals, host
    re-stage, BatchEncoder drain to containers: the pre-Transcoder way to
    re-compress an archive under a new config;
  * **Transcoder** — the same two fused engines composed on device: one
    upload, zero host syncs between decode and re-encode, one drain.

The pipeline section (``--pipeline``, or ``--mode pipeline`` alone)
measures the shared serving-engine layer's two scheduling axes on the same
archive:

  * **pipelined vs synchronous** — double-buffered host staging + h2d
    upload (bucket k+1 stages while bucket k computes) vs the strict
    serial loop, with the overlap efficiency (fraction of staging time
    hidden behind device compute) derived from the executor's stage
    timers;
  * **sharded vs single-device** — each bucket's batch axis split across
    the visible local devices (``--devices N`` caps how many; CI fakes 4
    via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``), reported
    as per-device scaling.

Both are byte-identical to the synchronous single-device path by
construction, so the section reports pure scheduling cost.  It also dumps
each engine's per-bucket padding/occupancy records (word/window/row fill
rates) — the measurement the ROADMAP's half-octave bucket-policy decision
asks for.

``--smoke`` runs tiny-size batched encode+decode+transcode only — the CI
guard that keeps the serving hot paths from rotting between perf PRs
(``--mode`` restricts both smoke and full runs to one section).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_signal, tables_for
from repro.core import DOMAIN_DEFAULTS, encode
from repro.core.codec import (
    _decode_device,
    _encode_stages_device,
    decode as hdecode,
)
from repro.core.config import CodecConfig
from repro.core.container import Container
from repro.core.metrics import prd
from repro.core.symlen import u32_to_words
from repro.data.signals import DATASETS, domain_of
from repro.serving.batch_decode import BatchDecoder
from repro.serving.batch_encode import DEFAULT_CHUNK_SIZE, BatchEncoder
from repro.serving.transcode import Transcoder

ART = "benchmarks/artifacts/throughput"

PRD_BINS = ((0.0, 2.0), (2.0, 4.0), (4.0, 6.0))


def _legacy_decode(container, tables):
    """The pre-BatchDecoder per-container path: static-argname jit, table
    pytree passed per call, blocking host sync."""
    hi, lo = container.words_u32()
    out = _decode_device(
        jnp.asarray(hi),
        jnp.asarray(lo),
        jnp.asarray(container.symlen, dtype=jnp.int32),
        tables.device_tables(),
        l_max=container.l_max,
        max_symlen=container.max_symlen,
        num_symbols=container.num_symbols,
        num_windows=container.num_windows,
        n=container.n,
        e=container.e,
        signal_length=container.signal_length,
        use_kernels=False,
    )
    return np.asarray(out)


def decode_gbps(container, tables, trials=5, decoder=None):
    """Steady-state single-container GB/s of the fused bucket decode,
    excluding host transfer (the paper's measurement convention): streams
    are staged on device once, tables/basis come from the decoder's plan
    cache, and trials time only the device dispatch + sync."""
    from repro.serving.batch_decode import _decode_bucket
    from repro.serving.engine import p2, symlen_bucket

    dec = decoder or BatchDecoder()
    plan = dec.plan_for(container, tables)
    w = container.num_words
    wp = p2(max(w, 1))
    hi = np.zeros(wp, np.uint32)
    lo = np.zeros(wp, np.uint32)
    sl = np.zeros(wp, np.int32)
    hi[:w], lo[:w] = container.words_u32()
    sl[:w] = container.symlen
    hi, lo, sl = jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(sl)
    kw = dict(
        l_max=plan.l_max,
        max_symlen=symlen_bucket(container.max_symlen),
        num_windows=p2(max(container.num_windows, 1)),
        n=plan.n, e=plan.e, use_kernels=dec.use_kernels,
    )
    _decode_bucket(hi, lo, sl, plan.tables, plan.basis, **kw).block_until_ready()
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        _decode_bucket(
            hi, lo, sl, plan.tables, plan.basis, **kw
        ).block_until_ready()
        times.append(time.perf_counter() - t0)
    out_bytes = container.signal_length * 4
    return [out_bytes / t / 1e9 for t in times]


_ARCHIVE_TABLES = {}


def _archive_tables(ds: str, domain_id: int):
    """Per-dataset tables carrying a distinct domain_id, so mixed batches
    route by Container.domain_id through the BatchDecoder."""
    from repro.core import calibrate
    from repro.data import make_signal

    key = (ds, domain_id)
    if key not in _ARCHIVE_TABLES:
        calib = np.concatenate(
            [make_signal(ds, 65536, seed=90 + i) for i in range(4)]
        )
        _ARCHIVE_TABLES[key] = calibrate(
            calib, DOMAIN_DEFAULTS[domain_of(ds)], domain_id=domain_id
        )
    return _ARCHIVE_TABLES[key]


def _mixed_signals(
    batch_size: int, seed: int = 0, log2_range=(14.0, 16.0)
):
    """Mixed-domain, mixed-length raw signals (+ per-signal routing).

    Alternates power and meteorological domains with strip lengths swept
    over a 4x range, so the legacy paths see many distinct static shapes.
    """
    rng = np.random.default_rng(seed)
    datasets = ["load_power", "temperature"]
    signals, domain_ids, by_id = [], [], {}
    for i in range(batch_size):
        dom_id = i % len(datasets)
        tables = _archive_tables(datasets[dom_id], dom_id)
        by_id[dom_id] = tables
        length = int(2 ** rng.uniform(*log2_range))  # e.g. 16k..64k samples
        signals.append(eval_signal(datasets[dom_id], length, seed=100 + i))
        domain_ids.append(dom_id)
    return signals, domain_ids, by_id


def _mixed_archive(batch_size: int, seed: int = 0, log2_range=(14.0, 16.0)):
    """A mixed-domain, mixed-length archive of ``batch_size`` containers."""
    signals, domain_ids, by_id = _mixed_signals(batch_size, seed, log2_range)
    containers = [
        encode(sig, by_id[dom]) for sig, dom in zip(signals, domain_ids)
    ]
    return containers, by_id


def _legacy_encode(sig, tables) -> Container:
    """The pre-BatchEncoder per-signal path: jitted DCT+quant+serial-scan
    packing with a blocking int(num_words) host sync per container."""
    cfg = tables.config
    signal = jnp.asarray(np.asarray(sig, np.float32).ravel())
    hi, lo, sl, num_words, n_windows = _encode_stages_device(
        signal, tables.device_tables(), cfg.n, cfg.e
    )
    nw = int(num_words)
    return Container(
        words=u32_to_words(np.asarray(hi[:nw]), np.asarray(lo[:nw])),
        symlen=np.asarray(sl[:nw]).astype(np.uint8),
        num_symbols=int(n_windows) * cfg.e,
        num_windows=int(n_windows),
        signal_length=int(signal.shape[0]),
        n=cfg.n,
        e=cfg.e,
        l_max=cfg.l_max,
        domain_id=tables.domain_id,
    )


def _pad_report(pad_records):
    """Aggregate an engine's per-bucket padding records into the JSON
    occupancy report (per-bucket detail + batch-level waste) — the uniform
    shape every batched section and the policy sweep emit."""
    records = [dict(r) for r in pad_records]
    report = {"buckets": records}
    for live_key, pad_key, name in (
        ("words", "words_padded", "word"),
        ("windows", "windows_padded", "window"),
        ("rows", "rows_padded", "row"),
    ):
        live = sum(r[live_key] for r in records
                   if r.get(live_key) is not None and pad_key in r)
        padded = sum(r[pad_key] for r in records
                     if r.get(live_key) is not None and pad_key in r)
        if padded:
            report[f"{name}_occupancy"] = live / padded
            report[f"{name}_padding_waste"] = 1.0 - live / padded
    return report


def bench_batched(fast: bool = False, log2_range=(14.0, 16.0), policy=None):
    """containers/sec + aggregate GB/s at batch sizes 1/8/64.

    Cold numbers are only unbiased in a fresh process (run() therefore runs
    this section FIRST, before anything warms the shared bucket-jit cache);
    each batch size draws distinct container lengths so the legacy loop
    can't coast on previously-compiled shapes.
    """
    results = {}
    batch_sizes = (1, 8) if fast else (1, 8, 64)
    for bs in batch_sizes:
        containers, by_id = _mixed_archive(bs, seed=bs, log2_range=log2_range)
        out_bytes = sum(c.signal_length * 4 for c in containers)

        # --- legacy per-container loop --------------------------------
        t0 = time.perf_counter()
        for c in containers:
            _legacy_decode(c, by_id[c.domain_id])
        loop_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        for c in containers:
            _legacy_decode(c, by_id[c.domain_id])
        loop_warm = time.perf_counter() - t0

        # --- batched engine -------------------------------------------
        dec = BatchDecoder(policy=policy)
        t0 = time.perf_counter()
        dec.decode(containers, by_id).block_until_ready()
        batch_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        dec.decode(containers, by_id).block_until_ready()
        batch_warm = time.perf_counter() - t0

        rec = {
            "batch_size": bs,
            "out_bytes": out_bytes,
            "loop_warm_s": loop_warm,
            "loop_cold_s": loop_cold,
            "batch_warm_s": batch_warm,
            "batch_cold_s": batch_cold,
            "loop_gbps": out_bytes / loop_warm / 1e9,
            "batch_gbps": out_bytes / batch_warm / 1e9,
            "loop_cps": bs / loop_warm,
            "batch_cps": bs / batch_warm,
            "speedup_warm": loop_warm / batch_warm,
            "speedup_cold": loop_cold / batch_cold,
            "dispatches": dec.stats.dispatches // dec.stats.batches,
            "policy": dec.scheduler.policy.name,
            "occupancy": _pad_report(dec.stats.bucket_pad),
        }
        results[bs] = rec
        emit(
            f"throughput/batched/bs{bs}",
            1e6 * batch_warm / bs,
            f"cps={rec['batch_cps']:.1f} GBps={rec['batch_gbps']:.3f} "
            f"speedup_warm={rec['speedup_warm']:.2f}x "
            f"speedup_cold={rec['speedup_cold']:.2f}x "
            f"dispatches={rec['dispatches']}",
        )
    return results


def bench_encode_batched(
    fast: bool = False,
    log2_range=(14.0, 16.0),
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    policy=None,
):
    """Encode-side mirror of bench_batched: signals/sec + GB/s ingested at
    batch sizes 1/8/64, legacy per-signal loop vs BatchEncoder, plus the
    chunk-padding CR loss of the parallel packer vs the sequential one.
    """
    results = {}
    batch_sizes = (1, 8) if fast else (1, 8, 64)
    for bs in batch_sizes:
        signals, domain_ids, by_id = _mixed_signals(
            bs, seed=1000 + bs, log2_range=log2_range
        )
        in_bytes = sum(s.size * 4 for s in signals)

        # --- legacy per-signal loop (serial packing scan) -------------
        t0 = time.perf_counter()
        legacy = [
            _legacy_encode(s, by_id[d]) for s, d in zip(signals, domain_ids)
        ]
        loop_cold = time.perf_counter() - t0
        # warm = median of 3 passes (single passes are too noisy on small
        # shared-CPU hosts to compare engines honestly)
        warm_times = []
        for _ in range(3):
            t0 = time.perf_counter()
            for s, d in zip(signals, domain_ids):
                _legacy_encode(s, by_id[d])
            warm_times.append(time.perf_counter() - t0)
        loop_warm = float(np.median(warm_times))

        # --- batched engine (chunk-parallel packing) ------------------
        enc = BatchEncoder(chunk_size=chunk_size, policy=policy)
        t0 = time.perf_counter()
        chunked = enc.encode(signals, by_id, domain_ids=domain_ids).to_host()
        batch_cold = time.perf_counter() - t0
        # drain included: both engines are timed to materialized Containers
        warm_times = []
        for _ in range(3):
            t0 = time.perf_counter()
            enc.encode(signals, by_id, domain_ids=domain_ids).to_host()
            warm_times.append(time.perf_counter() - t0)
        batch_warm = float(np.median(warm_times))

        # chunk-boundary padding: CR loss vs the sequential packer
        exact_words = sum(c.num_words for c in legacy)
        chunk_words = sum(c.num_words for c in chunked)
        cr_loss = (chunk_words - exact_words) / max(exact_words, 1)

        rec = {
            "batch_size": bs,
            "in_bytes": in_bytes,
            "loop_warm_s": loop_warm,
            "loop_cold_s": loop_cold,
            "batch_warm_s": batch_warm,
            "batch_cold_s": batch_cold,
            "loop_gbps": in_bytes / loop_warm / 1e9,
            "batch_gbps": in_bytes / batch_warm / 1e9,
            "loop_sps": bs / loop_warm,
            "batch_sps": bs / batch_warm,
            "speedup_warm": loop_warm / batch_warm,
            "speedup_cold": loop_cold / batch_cold,
            "dispatches": enc.stats.dispatches // enc.stats.batches,
            "chunk_size": chunk_size,
            "exact_words": exact_words,
            "chunked_words": chunk_words,
            "cr_loss": cr_loss,
            "policy": enc.scheduler.policy.name,
            "occupancy": _pad_report(enc.stats.bucket_pad),
        }
        results[bs] = rec
        emit(
            f"throughput/encode_batched/bs{bs}",
            1e6 * batch_warm / bs,
            f"sps={rec['batch_sps']:.1f} GBps={rec['batch_gbps']:.3f} "
            f"speedup_warm={rec['speedup_warm']:.2f}x "
            f"speedup_cold={rec['speedup_cold']:.2f}x "
            f"dispatches={rec['dispatches']} cr_loss={100 * cr_loss:.2f}%",
        )
    return results


def _migration_tables():
    """The archive-migration target: one coarser power-grid-style config
    (half the retained coefficients of the power default) under a fresh
    domain id — the 'tighter quantization for cold storage' scenario."""
    from repro.core import calibrate
    from repro.data import make_signal

    key = ("__migration__", 99)
    if key not in _ARCHIVE_TABLES:
        base = DOMAIN_DEFAULTS["power"]
        cfg = CodecConfig(
            n=base.n, e=max(base.e // 2, 1), b1=min(base.b1, 2),
            b2=max(base.e // 2, 1), mu=base.mu, alpha1=base.alpha1,
            a0_percentile=base.a0_percentile,
            scale_headroom=base.scale_headroom,
        )
        calib = np.concatenate(
            [make_signal("load_power", 65536, seed=70 + i) for i in range(4)]
        )
        _ARCHIVE_TABLES[key] = calibrate(calib, cfg, domain_id=99)
    return _ARCHIVE_TABLES[key]


def bench_transcode(
    fast: bool = False,
    log2_range=(14.0, 16.0),
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    policy=None,
):
    """Archive migration throughput: containers/sec re-compressed under a
    new (domain, config) at batch 1/8/64, three pipelines:

      * **per-container round trip** — the legacy paper-style loop (this
        file's baseline convention): one ``_decode_device`` + one
        ``_encode_stages_device`` per container, each with its own jit
        specialization, table pytree and blocking host sync;
      * **engine round trip** — BatchDecoder drain to host signals, host
        re-stage, BatchEncoder drain (the pre-Transcoder best);
      * **Transcoder** — the same two fused engines composed on device:
        zero host syncs between decode and re-encode, one drain.

    ``speedup_warm``/``speedup_cold`` follow the section convention and
    compare against the per-container loop; ``speedup_engines_warm`` is
    the honest engine-vs-engine number.  On CPU the engine round trip is
    already compute-bound (XLA decode+encode dominates; its extra host
    drain/re-stage is memcpy), so the engine gap is small warm — the
    device path's removed syncs/uploads are what matter on accelerators.
    Transcoder output is asserted byte-identical to the engine round trip
    once per batch size, so the comparison is pure pipeline cost.
    """
    results = {}
    batch_sizes = (1, 8) if fast else (1, 8, 64)
    dst = _migration_tables()
    for bs in batch_sizes:
        containers, by_id = _mixed_archive(
            bs, seed=3000 + bs, log2_range=log2_range
        )
        in_bytes = sum(c.compressed_bytes for c in containers)
        out_signal_bytes = sum(c.signal_length * 4 for c in containers)

        # --- legacy per-container round trip --------------------------
        def legacy_roundtrip():
            return [
                _legacy_encode(_legacy_decode(c, by_id[c.domain_id]), dst)
                for c in containers
            ]

        t0 = time.perf_counter()
        legacy_roundtrip()
        loop_cold = time.perf_counter() - t0
        warm_times = []
        for _ in range(3):
            t0 = time.perf_counter()
            legacy_roundtrip()
            warm_times.append(time.perf_counter() - t0)
        loop_warm = float(np.median(warm_times))
        loop_warm_min = float(np.min(warm_times))

        # --- batched-engine round trip --------------------------------
        # same policy as the Transcoder: chunked-mode encode bytes depend
        # on the bucket rounding, so the byte-identity assert below needs
        # both pipelines on one ladder
        def engine_roundtrip():
            sigs = BatchDecoder(policy=policy).decode(
                containers, by_id
            ).to_host()
            return BatchEncoder(
                chunk_size=chunk_size, policy=policy
            ).encode(sigs, dst).to_host()

        t0 = time.perf_counter()
        ref = engine_roundtrip()
        eng_cold = time.perf_counter() - t0
        warm_times = []
        for _ in range(3):
            t0 = time.perf_counter()
            engine_roundtrip()
            warm_times.append(time.perf_counter() - t0)
        eng_warm = float(np.median(warm_times))

        # --- device-resident Transcoder -------------------------------
        tc = Transcoder(chunk_size=chunk_size, policy=policy)
        t0 = time.perf_counter()
        got = tc.transcode(containers, by_id, dst).to_host()
        dev_cold = time.perf_counter() - t0
        warm_times = []
        for _ in range(3):
            t0 = time.perf_counter()
            tc.transcode(containers, by_id, dst).to_host()
            warm_times.append(time.perf_counter() - t0)
        dev_warm = float(np.median(warm_times))
        dev_warm_min = float(np.min(warm_times))

        for a, b in zip(got, ref):
            assert a.to_bytes() == b.to_bytes(), (
                "device-resident transcode diverged from the engine "
                "round trip"
            )

        rec = {
            "batch_size": bs,
            "in_bytes": in_bytes,
            "out_signal_bytes": out_signal_bytes,
            "loop_warm_s": loop_warm,
            "loop_cold_s": loop_cold,
            "engines_warm_s": eng_warm,
            "engines_cold_s": eng_cold,
            "device_warm_s": dev_warm,
            "device_cold_s": dev_cold,
            "loop_cps": bs / loop_warm,
            "engines_cps": bs / eng_warm,
            "device_cps": bs / dev_warm,
            "device_gbps": out_signal_bytes / dev_warm / 1e9,
            "speedup_warm": loop_warm / dev_warm,
            # min-of-passes ratio: the low-noise estimator a shared-CPU CI
            # runner needs (a background spike in ONE device pass should
            # not fail the smoke guard)
            "speedup_warm_best": loop_warm_min / dev_warm_min,
            "speedup_cold": loop_cold / dev_cold,
            "speedup_engines_warm": eng_warm / dev_warm,
            "speedup_engines_cold": eng_cold / dev_cold,
            "chunk_size": chunk_size,
            "policy": tc.decoder.scheduler.policy.name,
            "occupancy": {
                "decode": _pad_report(tc.decoder.stats.bucket_pad),
                "encode": _pad_report(tc.encoder.stats.bucket_pad),
            },
        }
        results[bs] = rec
        emit(
            f"throughput/transcode/bs{bs}",
            1e6 * dev_warm / bs,
            f"cps={rec['device_cps']:.1f} GBps={rec['device_gbps']:.3f} "
            f"speedup_warm={rec['speedup_warm']:.2f}x "
            f"speedup_cold={rec['speedup_cold']:.2f}x "
            f"vs_engines_warm={rec['speedup_engines_warm']:.2f}x",
        )
    return results


def bench_pipeline(
    fast: bool = False,
    log2_range=(14.0, 16.0),
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    num_devices: int = 0,
    policy=None,
):
    """The serving-engine scheduling axes on one mixed archive:

      * synchronous (pipeline off, single device) — the strict
        stage->upload->dispatch loop;
      * pipelined (double-buffered staging, single device) — overlap
        efficiency = fraction of the measured staging/upload time hidden
        behind device compute;
      * sharded (pipelined + every visible/requested device) — per-device
        scaling vs the single-device pipelined run.

    All three produce byte-identical outputs (asserted once per section),
    so the numbers compare scheduling alone.  Per-bucket padding
    occupancy is reported from the engines' own stats.
    """
    import jax

    local = jax.local_devices()
    devs = local[:num_devices] if num_devices else local
    bs = 16 if fast else 64
    containers, by_id = _mixed_archive(
        bs, seed=7000 + bs, log2_range=log2_range
    )
    signals, domain_ids, _ = _mixed_signals(
        bs, seed=7000 + bs, log2_range=log2_range
    )
    dst = _migration_tables()
    passes = 3

    def measure(make_engine, run, executors_of):
        """(cold_s, warm_s, upload_s per warm pass, engine) for one arm."""
        eng = make_engine()
        t0 = time.perf_counter()
        ref = run(eng)
        cold = time.perf_counter() - t0
        before = sum(ex.stats.upload_s for ex in executors_of(eng))
        times = []
        for _ in range(passes):
            t0 = time.perf_counter()
            run(eng)
            times.append(time.perf_counter() - t0)
        upload = (
            sum(ex.stats.upload_s for ex in executors_of(eng)) - before
        ) / passes
        return cold, float(np.median(times)), upload, ref

    def arm(make_engine, run, executors_of, byte_key):
        sync_cold, sync_warm, sync_upload, sync_ref = measure(
            lambda: make_engine(pipeline=False, devices=None),
            run, executors_of,
        )
        pipe_cold, pipe_warm, pipe_upload, pipe_ref = measure(
            lambda: make_engine(pipeline=True, devices=None),
            run, executors_of,
        )
        assert byte_key(pipe_ref) == byte_key(sync_ref), (
            "pipelined output diverged from synchronous"
        )
        rec = {
            "sync_warm_s": sync_warm,
            "sync_cold_s": sync_cold,
            "pipe_warm_s": pipe_warm,
            "pipe_cold_s": pipe_cold,
            "stage_upload_s": pipe_upload,
            "pipeline_speedup_warm": sync_warm / pipe_warm,
            # fraction of the staging/upload time hidden behind device
            # compute (clipped: noise can make the saving exceed the
            # measured staging time on a loaded host)
            "overlap_efficiency": float(np.clip(
                (sync_warm - pipe_warm) / max(pipe_upload, 1e-9), 0.0, 1.0
            )),
        }
        if len(devs) > 1:
            shard_cold, shard_warm, _, shard_ref = measure(
                lambda: make_engine(pipeline=True, devices=devs),
                run, executors_of,
            )
            assert byte_key(shard_ref) == byte_key(sync_ref), (
                "sharded output diverged from synchronous"
            )
            rec.update({
                "shard_warm_s": shard_warm,
                "shard_cold_s": shard_cold,
                "device_scaling_warm": pipe_warm / shard_warm,
            })
        return rec

    sig_bytes = lambda sigs: [s.tobytes() for s in sigs]
    cont_bytes = lambda cs: [c.to_bytes() for c in cs]

    results = {
        "batch_size": bs,
        "devices_visible": len(local),
        "devices_used": len(devs),
        "decode": arm(
            lambda **kw: BatchDecoder(policy=policy, **kw),
            lambda eng: eng.decode(containers, by_id).to_host(),
            lambda eng: [eng.executor],
            sig_bytes,
        ),
        "encode": arm(
            lambda **kw: BatchEncoder(
                chunk_size=chunk_size, policy=policy, **kw
            ),
            lambda eng: eng.encode(
                signals, by_id, domain_ids=domain_ids
            ).to_host(),
            lambda eng: [eng.executor],
            cont_bytes,
        ),
        "transcode": arm(
            lambda **kw: Transcoder(
                chunk_size=chunk_size, policy=policy, **kw
            ),
            lambda eng: eng.transcode(containers, by_id, dst).to_host(),
            lambda eng: [eng.decoder.executor, eng.encoder.executor],
            cont_bytes,
        ),
    }

    # padding occupancy per bucket, from one fresh pass of each engine
    dec = BatchDecoder(
        devices=devs if len(devs) > 1 else None, policy=policy
    )
    dec.decode(containers, by_id).to_host()
    enc = BatchEncoder(
        chunk_size=chunk_size, policy=policy,
        devices=devs if len(devs) > 1 else None,
    )
    enc.encode(signals, by_id, domain_ids=domain_ids).to_host()
    results["policy"] = dec.scheduler.policy.name
    results["decode"]["occupancy"] = _pad_report(dec.stats.bucket_pad)
    results["encode"]["occupancy"] = _pad_report(enc.stats.bucket_pad)

    for mode in ("decode", "encode", "transcode"):
        rec = results[mode]
        extra = (
            f" devices={len(devs)} "
            f"scaling={rec['device_scaling_warm']:.2f}x"
            if "device_scaling_warm" in rec else ""
        )
        emit(
            f"throughput/pipeline/{mode}/bs{bs}",
            1e6 * rec["pipe_warm_s"] / bs,
            f"pipeline_speedup={rec['pipeline_speedup_warm']:.2f}x "
            f"overlap_eff={rec['overlap_efficiency']:.2f}{extra}",
        )
    return results


def bench_policy_sweep(
    log2_range=(14.0, 16.0),
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    batch_size: int = 16,
):
    """The ROADMAP item-4 measurement: one mixed archive of ``batch_size``
    containers drained under each bucket policy (p2 / half-octave /
    cost-balanced), reporting per-policy padding occupancy, warm latency,
    and the fused-decode compile count each ladder added — the numbers the
    bucket-policy decision is made from.  Written to
    ``BENCH_bucket_policy.json`` (uploaded by the CI ``tuning`` leg).

    Decoded outputs are asserted byte-identical across policies (bucket
    edges pad with dead words, they never change samples).  Encoded word
    totals are reported per policy, not asserted: chunked-mode packing
    pads per chunk, so its stream length legitimately depends on the
    bucket the signal landed in (exact mode is policy-invariant — that
    contract lives in the engine test suite).
    """
    from repro.serving.batch_decode import bucket_cache_size
    from repro.tuning.policy import POLICY_NAMES

    bs = batch_size
    containers, by_id = _mixed_archive(
        bs, seed=5000 + bs, log2_range=log2_range
    )
    signals, domain_ids, _ = _mixed_signals(
        bs, seed=5000 + bs, log2_range=log2_range
    )
    results = {"batch_size": bs, "policies": {}}
    ref_sig = None
    for pol in POLICY_NAMES:
        dec = BatchDecoder(policy=pol)
        c0 = bucket_cache_size() or 0
        t0 = time.perf_counter()
        sigs = dec.decode(containers, by_id).to_host()
        dec_cold = time.perf_counter() - t0
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            dec.decode(containers, by_id).to_host()
            times.append(time.perf_counter() - t0)
        dec_warm = float(np.median(times))
        dec_compiles = (bucket_cache_size() or 0) - c0

        got = [s.tobytes() for s in sigs]
        if ref_sig is None:
            ref_sig = got
        else:
            assert got == ref_sig, (
                f"decode bytes diverged under policy {pol}"
            )

        enc = BatchEncoder(chunk_size=chunk_size, policy=pol)
        t0 = time.perf_counter()
        conts = enc.encode(signals, by_id, domain_ids=domain_ids).to_host()
        enc_cold = time.perf_counter() - t0
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            enc.encode(signals, by_id, domain_ids=domain_ids).to_host()
            times.append(time.perf_counter() - t0)
        enc_warm = float(np.median(times))

        dec_occ = _pad_report(dec.stats.bucket_pad)
        enc_occ = _pad_report(enc.stats.bucket_pad)
        results["policies"][pol] = {
            "decode": {
                "cold_s": dec_cold,
                "warm_s": dec_warm,
                "new_bucket_compiles": dec_compiles,
                "dispatches": dec.stats.dispatches // dec.stats.batches,
                "occupancy": dec_occ,
            },
            "encode": {
                "cold_s": enc_cold,
                "warm_s": enc_warm,
                "dispatches": enc.stats.dispatches // enc.stats.batches,
                "total_words": sum(c.num_words for c in conts),
                "occupancy": enc_occ,
            },
        }
        emit(
            f"throughput/policy/{pol}/bs{bs}",
            1e6 * dec_warm / bs,
            f"word_waste={dec_occ.get('word_padding_waste', 0.0):.3f} "
            f"row_waste={enc_occ.get('row_padding_waste', 0.0):.3f} "
            f"compiles=+{dec_compiles} enc_warm_s={enc_warm:.3f}",
        )

    # the policy claim, asserted on the measurement itself: the finer
    # ladders must cut the p2 word-padding waste (absolute levels ride on
    # the drawn lengths and live in the JSON)
    p2_waste = results["policies"]["p2"]["decode"]["occupancy"].get(
        "word_padding_waste", 0.0
    )
    finer = {
        pol: results["policies"][pol]["decode"]["occupancy"].get(
            "word_padding_waste", 0.0
        )
        for pol in ("half-octave", "cost-balanced")
    }
    assert min(finer.values()) < p2_waste, (
        f"finer bucket ladders did not reduce p2 word waste: "
        f"p2={p2_waste:.3f} {finer}"
    )
    # the acceptance target (25% -> <=15%); the archive is seeded, so this
    # is deterministic — measured 10.0% (half-octave) / 6.9%
    # (cost-balanced) vs 25.0% (p2) on the CPU smoke
    assert min(finer.values()) <= 0.15, (
        f"best finer-ladder word waste {min(finer.values()):.3f} > 15%"
    )
    results["word_waste"] = {"p2": p2_waste, **finer}
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_bucket_policy.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


def smoke(mode: str = "all", pipeline: bool = False, num_devices: int = 0,
          use_kernels: bool = False, policy: str = None):
    """Tiny-size encode+decode+transcode batched smoke for CI: exercises
    the serving hot paths (bucketing, plan caches, fused dispatches,
    chunked packing, the device-resident transcode — and, with
    ``--pipeline``, the double-buffered/sharded executor axes) end to end
    in well under a minute, and sanity-checks the speedup/CR numbers are
    finite.  ``--use-kernels`` flips every engine the smoke constructs
    onto the fused Pallas path (via the FPTC_USE_KERNELS process default),
    so the same sections report the kernel-path dispatch counts/timings —
    bytes are identical by construction, so every assertion still holds.
    ``--policy`` pins the bucket ladder (``--policy sweep`` instead runs
    the per-policy comparison section alone)."""
    if use_kernels:
        os.environ["FPTC_USE_KERNELS"] = "1"
    os.makedirs(ART, exist_ok=True)
    if policy == "sweep":
        bench_policy_sweep(log2_range=(11.0, 13.0), chunk_size=128)
        print("policy sweep OK")
        return
    results = {"config": {"use_kernels": use_kernels, "policy": policy}}
    if mode in ("all", "decode"):
        results["batched"] = bench_batched(
            fast=True, log2_range=(11.0, 12.0), policy=policy
        )
    if mode in ("all", "encode"):
        # chunk_size=128 so even tiny smoke signals span several chunks —
        # the multi-chunk pack lanes and the host stitch must execute
        results["encode_batched"] = bench_encode_batched(
            fast=True, log2_range=(11.0, 12.0), chunk_size=128,
            policy=policy,
        )
    if mode in ("all", "transcode"):
        # fast=False so batch 64 runs even in the smoke (the acceptance
        # measurement is the bs-64 device-vs-roundtrip speedup); tiny
        # signals keep it fast
        results["transcode"] = bench_transcode(
            fast=False, log2_range=(11.0, 12.0), chunk_size=128,
            policy=policy,
        )
    if pipeline or mode == "pipeline":
        # LAST: its passes warm the same tiny bucket shapes the batched
        # sections measure cold, so running it first would bias their
        # speedup_cold numbers (the pipeline section itself has no
        # cold-cache claim — its cold numbers are labeled as such)
        results["pipeline"] = bench_pipeline(
            fast=True, log2_range=(11.0, 12.0), chunk_size=128,
            num_devices=num_devices, policy=policy,
        )
        for m in ("decode", "encode", "transcode"):
            rec = results["pipeline"][m]
            assert np.isfinite(rec["pipeline_speedup_warm"]), (m, rec)
    for section, recs in results.items():
        if section in ("pipeline", "config"):
            continue  # different shape; pipeline asserted above
        for bs, rec in recs.items():
            assert np.isfinite(rec["speedup_warm"]), (section, bs, rec)
    if "transcode" in results:
        # acceptance guard: at batch 64 the device-resident path must beat
        # the per-container round trip comfortably even on CPU (judged on
        # the min-of-passes ratio so one background-load spike on a shared
        # runner can't flake the smoke)
        rec = results["transcode"][64]
        best = max(rec["speedup_warm"], rec["speedup_warm_best"])
        assert best >= 1.5, (
            f"transcode bs64 speedup {best:.2f}x < 1.5x", rec,
        )
    if "encode_batched" in results:
        assert any(
            rec["chunked_words"] > rec["exact_words"]
            for rec in results["encode_batched"].values()
        ), "smoke never exercised multi-chunk packing"
    with open(os.path.join(ART, "throughput_smoke.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    print("smoke OK")


def run(fast: bool = False, mode: str = "all", pipeline: bool = False,
        num_devices: int = 0, policy: str = None):
    os.makedirs(ART, exist_ok=True)
    if policy == "sweep":
        bench_policy_sweep()
        return
    datasets = ["mitbih", "load_power", "wind_speed"] if fast else sorted(
        DATASETS
    )
    results = {}
    # batched sections first: their cold-vs-cold comparisons are only fair
    # while the process-wide bucket jit caches are empty
    if mode in ("all", "decode"):
        results["batched"] = bench_batched(fast, policy=policy)
    if mode in ("all", "encode"):
        results["encode_batched"] = bench_encode_batched(fast, policy=policy)
    if mode in ("all", "transcode"):
        results["transcode"] = bench_transcode(fast, policy=policy)
    if pipeline or mode == "pipeline":
        results["pipeline"] = bench_pipeline(
            fast, num_devices=num_devices, policy=policy
        )
    if mode != "all":
        with open(os.path.join(ART, f"throughput_{mode}.json"), "w") as f:
            json.dump(results, f, indent=1, default=float)
        return
    decoder = BatchDecoder()  # shared plan + jit cache across datasets
    for ds in datasets:
        dom = domain_of(ds)
        base = DOMAIN_DEFAULTS[dom]
        sig = eval_signal(ds, 1 << 20)  # 4 MB strips
        per_bin = {}
        for n, e in [(32, max(base.e // 2, 1)), (32, base.e),
                     (32, min(base.e * 2, 32))]:
            cfg = CodecConfig(
                n=n, e=e, b1=min(base.b1, e), b2=e, mu=base.mu,
                alpha1=base.alpha1, a0_percentile=base.a0_percentile,
                scale_headroom=base.scale_headroom,
            )
            tables = tables_for(ds, cfg)
            c = encode(sig, tables)
            p = prd(sig, hdecode(c, tables))
            gbps = decode_gbps(c, tables, decoder=decoder)
            for lo_b, hi_b in PRD_BINS:
                if lo_b <= p < hi_b:
                    key = f"({lo_b:.0f},{hi_b:.0f}]"
                    if key not in per_bin or np.mean(gbps) > np.mean(
                        per_bin[key]["gbps"]
                    ):
                        per_bin[key] = {
                            "prd": p, "cr": c.compression_ratio,
                            "gbps": gbps, "e": e, "n": n,
                        }
        results[ds] = per_bin
        for key, rec in per_bin.items():
            emit(
                f"throughput/{ds}/prd{key}",
                1e6 * (1 << 22) / (np.mean(rec["gbps"]) * 1e9),
                f"GBps_mean={np.mean(rec['gbps']):.3f} "
                f"GBps_min={np.min(rec['gbps']):.3f} CR={rec['cr']:.1f} "
                f"PRD={rec['prd']:.2f}",
            )
    with open(os.path.join(ART, "throughput.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer sizes/datasets")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny CI smoke of the batched serving hot paths only",
    )
    ap.add_argument(
        "--mode",
        choices=["all", "decode", "encode", "transcode", "pipeline"],
        default="all",
        help="restrict to one batched section (e.g. --mode transcode for "
        "the archive-migration arm, --mode pipeline for the "
        "scheduling-axes section alone)",
    )
    ap.add_argument(
        "--pipeline",
        action="store_true",
        help="also measure the pipelined/sharded executor axes "
        "(sync-vs-double-buffered and 1-vs-N-device, with overlap "
        "efficiency and per-bucket padding occupancy in the JSON)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=0,
        help="cap the local devices the sharded arm uses (0 = all "
        "visible; fake N CPU devices with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument(
        "--use-kernels",
        action="store_true",
        help="run every engine the smoke constructs on the fused Pallas "
        "kernel path (interpret mode off-TPU; bytes identical to the XLA "
        "path by construction)",
    )
    ap.add_argument(
        "--policy",
        choices=["p2", "half-octave", "cost-balanced", "sweep"],
        default=None,
        help="bucket-edge policy for every engine the benchmark "
        "constructs (default: FPTC_BUCKET_POLICY, else p2); 'sweep' "
        "instead runs the per-policy occupancy/latency/compile-count "
        "comparison and writes BENCH_bucket_policy.json",
    )
    args = ap.parse_args()
    if args.smoke:
        smoke(mode=args.mode, pipeline=args.pipeline,
              num_devices=args.devices, use_kernels=args.use_kernels,
              policy=args.policy)
    else:
        if args.use_kernels:
            os.environ["FPTC_USE_KERNELS"] = "1"
        run(fast=args.fast, mode=args.mode, pipeline=args.pipeline,
            num_devices=args.devices, policy=args.policy)
