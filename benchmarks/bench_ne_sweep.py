"""Fig. 14: decode throughput as a function of (DCT_SIZE, ENCODED_COEFFS)
on the MIT-BIH analog.  Reproduces: throughput inversely proportional to E;
peak at N=32 for low E."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.bench_throughput import decode_gbps
from benchmarks.common import emit, eval_signal, tables_for
from repro.core import DOMAIN_DEFAULTS, encode
from repro.core.config import CodecConfig

ART = "benchmarks/artifacts/ne_sweep"


def run(fast: bool = False):
    os.makedirs(ART, exist_ok=True)
    sig = eval_signal("mitbih", 1 << 19)
    base = DOMAIN_DEFAULTS["biomedical"]
    grid = {}
    ns = (16, 32, 64) if not fast else (32,)
    for n in ns:
        for e in (2, 4, 8, 16):
            if e > n:
                continue
            cfg = CodecConfig(
                n=n, e=e, b1=min(2, e), b2=e, mu=base.mu,
                a0_percentile=base.a0_percentile,
            )
            tables = tables_for("mitbih", cfg)
            c = encode(sig, tables)
            gbps = float(np.mean(decode_gbps(c, tables, trials=3)))
            grid[f"n{n}_e{e}"] = {"n": n, "e": e, "gbps": gbps,
                                  "cr": c.compression_ratio}
            emit(f"ne_sweep/n{n}_e{e}", 0.0,
                 f"GBps={gbps:.3f} CR={c.compression_ratio:.1f}")
    with open(os.path.join(ART, "grid.json"), "w") as f:
        json.dump(grid, f, indent=1)


if __name__ == "__main__":
    run()
