"""Attribute collective wire bytes to cross-pod vs intra-pod links.

Decodes each collective's replica_groups (iota form [G,S]<=[dims]T(perm)
or explicit lists) against the 2x16x16 device layout (pod stride = 256)
and sums trip-count-weighted bytes whose groups span the pod boundary.
This is the measurement behind EXPERIMENTS.md §Perf iteration 7.

  PYTHONPATH=src python benchmarks/pod_attribution.py \
      benchmarks/artifacts/dryrun/<cell>.hlo.gz ...
"""
import gzip, re, sys
import numpy as np
sys.path.insert(0, 'src')
from repro.analysis import hlo_cost as H

IOTA = re.compile(r'replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?')
LIST = re.compile(r'replica_groups=\{\{([\d,]+)\}')

def groups_cross_pod(ln, pod_devices=256):
    m = IOTA.search(ln)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(',')]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(',')])
        ids = ids.reshape(g, s)
        p_ids = ids // pod_devices; return bool((p_ids.max(axis=1) - p_ids.min(axis=1)).max() > 0)
    m = LIST.search(ln)
    if m:
        ids = np.array([int(x) for x in m.group(1).split(',')])
        p_ids = ids // pod_devices; return bool(p_ids.max() - p_ids.min() > 0)
    return False  # no groups = single-device/within-partition

def pod_bytes(path):
    txt = gzip.open(path, 'rt').read()
    p = H._Parser(txt)
    trips = {}
    for cname, lines in p.computations.items():
        for ln in lines:
            m = H._OP_LINE.match(ln)
            if m and m.group(3) == 'while':
                cb = H._COND_BODY.search(ln)
                if cb: trips[cb.group(2)] = p._trip_count(cb.group(1))
    cross = intra = cross_f32 = 0
    for cname, lines in p.computations.items():
        mult = trips.get(cname, 1) or 1
        for ln in lines:
            m = re.search(r'=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(', ln)
            if not m: continue
            size = H._shape_bytes(m.group(1)) * mult
            if groups_cross_pod(ln):
                cross += size
                if 'f32[' in m.group(1): cross_f32 += size
            else:
                intra += size
    return cross, intra, cross_f32

for path in sys.argv[1:]:
    c, i, cf = pod_bytes(path)
    c_tpu = c - 0.5*cf
    print(f"{path.split('/')[-1]:58s} cross-pod {c/1e9:8.2f} GB (tpu-adj {c_tpu/1e9:8.2f})   intra {i/1e9:9.2f} GB")
