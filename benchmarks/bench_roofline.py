"""§Roofline: three-term roofline per (arch x shape x mesh) from dry-run
artifacts.

  compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective term = collective_bytes / (chips x 50e9 B/s ICI per link)

HLO_FLOPs / bytes come from compiled.cost_analysis(); collective bytes are
parsed from the optimized HLO (dryrun.collective_bytes).  cost_analysis on
the CPU backend reports *per-partition* numbers for SPMD-compiled modules,
so terms divide by chips only where the quantity is whole-module.  We treat
cost_analysis flops/bytes as per-device (XLA reports the per-partition
module after SPMD partitioning) and collective bytes as per-device link
traffic.

MODEL_FLOPS uses 6*N*D (dense) or 6*N_active*D (MoE) with D = tokens
processed per step; the ratio MODEL_FLOPS / (HLO_FLOPs x chips) flags
remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

from benchmarks.common import emit

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

ART_IN = "benchmarks/artifacts/dryrun"
ART_OUT = "benchmarks/artifacts/roofline.json"

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}


def analyze(rec: Dict) -> Dict:
    chips = rec["n_devices"]
    flops_dev = rec["flops"]  # per-partition (SPMD) module FLOPs
    bytes_dev = rec["bytes_accessed"]
    coll_dev = rec["collective_bytes_total"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / ICI_BW

    terms = {
        "compute": t_compute, "memory": t_memory, "collective": t_collective
    }
    dominant = max(terms, key=terms.get)

    tokens = SHAPE_TOKENS.get(rec["shape"], 0)
    n_active = rec["params_active"]
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_total = flops_dev * chips
    useful = model_flops / hlo_total if hlo_total else 0.0

    bound = max(terms.values())
    mfu_bound = (model_flops / chips / PEAK_FLOPS) / bound if bound else 0.0

    return {
        **{k: rec[k] for k in ("arch", "shape", "kind", "mesh", "multi_pod",
                               "compression")},
        "terms_s": {k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": round(useful, 4),
        "roofline_bound_s": round(bound, 6),
        "mfu_upper_bound": round(mfu_bound, 4),
        "collective_breakdown": rec.get("collective_bytes", {}),
        "memory_resident_bytes": rec["memory"].get("resident_estimate_bytes"),
    }


def run(fast: bool = False):
    del fast
    rows = []
    for path in sorted(glob.glob(os.path.join(ART_IN, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        # re-analyze persisted HLO with the current cost model (metric fixes
        # apply without recompiling the cell)
        hlo_path = path[: -len(".json")] + ".hlo.gz"
        if os.path.exists(hlo_path):
            import gzip

            from repro.analysis import analyze_hlo

            with gzip.open(hlo_path, "rt") as f:
                hc = analyze_hlo(f.read())
            rec["flops"] = hc.flops
            rec["bytes_accessed"] = hc.hbm_bytes
            # headline collective term uses the bf16/TPU-adjusted wire bytes
            # (the CPU lowering upcasts bf16 compute to f32 before SPMD —
            # see HloCost.collective_bytes_tpu); raw bytes kept alongside.
            rec["collective_bytes_total"] = hc.collective_bytes_tpu
            rec["collective_bytes_raw_f32_lowering"] = hc.collective_bytes
            rec["collective_bytes"] = hc.collective_by_op
        row = analyze(rec)
        rows.append(row)
        emit(
            f"roofline/{row['arch']}/{row['shape']}/"
            f"{'mp' if row['multi_pod'] else 'sp'}"
            + (f"/{row['compression']}" if row["compression"] != "none"
               else ""),
            row["roofline_bound_s"] * 1e6,
            f"dominant={row['dominant']} "
            f"compute={row['terms_s']['compute']:.4f}s "
            f"memory={row['terms_s']['memory']:.4f}s "
            f"collective={row['terms_s']['collective']:.4f}s "
            f"useful={row['useful_flops_ratio']:.3f} "
            f"mfu_bound={row['mfu_upper_bound']:.3f}",
        )
    with open(ART_OUT, "w") as f:
        json.dump(rows, f, indent=1)
    if not rows:
        emit("roofline/no_artifacts", 0.0,
             "run repro.launch.dryrun first")


if __name__ == "__main__":
    run()
