"""Fig. 8/9: rate-distortion curves — parameter sweep + Pareto extraction,
now swept over BOTH container versions (v2 vs the v3 coding stage).

Sweeps (N, E) per dataset exactly as the paper does ("the sweep is performed
over all lossy parameters but focused primarily on N and E"), maps each
point to (PRD, CR), and extracts the Pareto front.  Every sweep point is
additionally encoded under the container-v3 coding grid (windowed
predictors on the low bands + zero-plane suppression) with the best v3
coding kept per point.  The v3 stage is a LOSSLESS re-coding of the
quantized levels, so each (v2, v3) pair sits at exactly matched PRD/PSNR —
the frontier moves iff the bytes move, which makes the per-point CR
comparison the ratio/quality-frontier acceptance check.

Results land in benchmarks/artifacts/rd/<dataset>.json (per-dataset, the
layout bench_reconstruction's Fig. 11 pass consumes, with the v3 columns
added) and the cross-dataset summary in benchmarks/artifacts/BENCH_rd.json
(what the CI `ratio` job uploads).  ``--smoke`` trims the sweep to one
power + one meteorological dataset and asserts the v3 frontier strictly
dominates v2 on them at matched PSNR.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, eval_signal, tables_for
from repro.core import DOMAIN_DEFAULTS
from repro.core.codec import roundtrip_metrics
from repro.core.config import CodecConfig
from repro.data.signals import DATASETS, domain_of

ART = "benchmarks/artifacts/rd"
BENCH_JSON = "benchmarks/artifacts/BENCH_rd.json"

SWEEP = [
    # (n, e_fraction) grid — e = max(1, int(n * frac))
    (16, 1.0), (16, 0.5), (16, 0.25),
    (32, 1.0), (32, 0.5), (32, 0.25), (32, 0.125),
    (64, 0.5), (64, 0.25), (64, 0.125), (64, 0.0625),
]
SMOKE_SWEEP = [(32, 0.5), (32, 0.25), (64, 0.25)]

# the v3 coding grid layered on every sweep point; the best ratio wins the
# point (predict_bands clamps to e)
V3_CODINGS = [
    dict(predictor="delta", predict_bands=1, zero_planes=False),
    dict(predictor="delta", predict_bands=2, zero_planes=False),
    dict(predictor="delta", predict_bands=2, zero_planes=True),
    dict(predictor="linear2", predict_bands=2, zero_planes=False),
]
SMOKE_DATASETS = ["load_power", "temperature"]  # power + meteorological


def pareto_front(points):
    """Points: list of (prd, cr).  Front: max CR at each PRD (lower-left
    dominated points removed)."""
    pts = sorted(points)
    front = []
    best_cr = -1.0
    for prd, cr in pts:
        if cr > best_cr:
            front.append((prd, cr))
            best_cr = cr
    return front


def _sweep_cfg(base, n, frac):
    e = max(1, int(n * frac))
    return CodecConfig(
        n=n, e=e, b1=min(base.b1, e), b2=e, mu=base.mu,
        alpha1=base.alpha1, a0_percentile=base.a0_percentile,
        scale_headroom=base.scale_headroom,
    )


def _best_v3(ds, sig, cfg):
    """Best v3 (CR, PRD, coding-name) over the coding grid at this point."""
    best = None
    for kw in V3_CODINGS:
        kw = dict(kw, predict_bands=min(kw["predict_bands"], cfg.e))
        cfg3 = cfg.replace(**kw)
        try:
            cr, prd = roundtrip_metrics(sig, tables_for(ds, cfg3))
        except Exception:
            continue
        name = (f"{cfg3.predictor}/{cfg3.predict_bands}"
                f"{'+zp' if cfg3.zero_planes else ''}")
        if best is None or cr > best[0]:
            best = (float(cr), float(prd), name)
    return best


def run(fast: bool = False, smoke: bool = False):
    os.makedirs(ART, exist_ok=True)
    if smoke:
        datasets, sweep = SMOKE_DATASETS, SMOKE_SWEEP
    elif fast:
        datasets, sweep = ["mitbih", "load_power"], SWEEP
    else:
        datasets, sweep = sorted(DATASETS), SWEEP
    sig_len = 32768 if smoke else 65536

    summary = {}
    for ds in datasets:
        dom = domain_of(ds)
        base = DOMAIN_DEFAULTS[dom]
        sig = eval_signal(ds, sig_len)
        points, points_v3 = [], []
        for n, frac in sweep:
            cfg = _sweep_cfg(base, n, frac)
            try:
                cr, prd = roundtrip_metrics(sig, tables_for(ds, cfg))
            except Exception:
                continue
            points.append((float(prd), float(cr), n, cfg.e))
            v3 = _best_v3(ds, sig, cfg)
            if v3 is not None:
                cr3, prd3, coding = v3
                points_v3.append((prd3, cr3, n, cfg.e, coding))
        front = pareto_front([(p, c) for p, c, _, _ in points])
        front_v3 = pareto_front([(p, c) for p, c, _, _, _ in points_v3])
        # best CR within the paper's high-fidelity band (PRD <= 5%; 2% seismic)
        band = 2.0 if dom == "seismic" else 5.0
        in_band = [c for p, c in front if p <= band]
        best = max(in_band) if in_band else 0.0
        in_band_v3 = [c for p, c in front_v3 if p <= band]
        best_v3 = max(in_band_v3) if in_band_v3 else 0.0

        # matched-PSNR frontier comparison: the v3 stage is lossless over
        # the quantized levels, so point i of both sweeps shares one PRD —
        # v3 strictly dominates iff it packs MORE ratio at every point
        matched = [
            (p2[1], p3[1], p3[4])
            for p2, p3 in zip(points, points_v3)
        ]
        dominates = bool(matched) and all(c3 > c2 for c2, c3, _ in matched)
        mean_gain = (
            sum(c3 / c2 for c2, c3, _ in matched) / len(matched)
            if matched else 0.0
        )

        with open(os.path.join(ART, f"{ds}.json"), "w") as f:
            json.dump(
                {"dataset": ds, "domain": dom, "points": points,
                 "pareto": front, "best_cr_in_band": best, "band": band,
                 "points_v3": points_v3, "pareto_v3": front_v3,
                 "best_cr_in_band_v3": best_v3,
                 "v3_dominates": dominates, "v3_mean_cr_gain": mean_gain},
                f, indent=1,
            )
        summary[ds] = {
            "domain": dom, "band": band,
            "best_cr_in_band": best, "best_cr_in_band_v3": best_v3,
            "v3_dominates": dominates, "v3_mean_cr_gain": mean_gain,
            "matched_points": matched,
        }
        emit(
            f"rd_pareto/{ds}", 0.0,
            f"best_CR@PRD<={band:.0f}%={best:.1f}x "
            f"v3={best_v3:.1f}x gain={mean_gain:.3f}x "
            f"dominates={dominates} front_points={len(front)}",
        )

    os.makedirs(os.path.dirname(BENCH_JSON), exist_ok=True)
    with open(BENCH_JSON, "w") as f:
        json.dump(summary, f, indent=1)

    if smoke:
        # acceptance gate (CI `ratio` job): on the power + meteorological
        # domains the v3 frontier must strictly dominate v2 at matched PSNR
        for ds in SMOKE_DATASETS:
            assert summary[ds]["v3_dominates"], (
                f"v3 frontier does not dominate v2 on {ds}: "
                f"{summary[ds]['matched_points']}"
            )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast, smoke=args.smoke)
