"""Fig. 8/9: rate-distortion curves — parameter sweep + Pareto extraction.

Sweeps (N, E) per dataset exactly as the paper does ("the sweep is performed
over all lossy parameters but focused primarily on N and E"), maps each
point to (PRD, CR), and extracts the Pareto front.  Results land in
benchmarks/artifacts/rd/<dataset>.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit, eval_signal, tables_for, time_fn
from repro.core import DOMAIN_DEFAULTS
from repro.core.codec import roundtrip_metrics
from repro.core.config import CodecConfig
from repro.data.signals import DATASETS, domain_of

ART = "benchmarks/artifacts/rd"

SWEEP = [
    # (n, e_fraction) grid — e = max(1, int(n * frac))
    (16, 1.0), (16, 0.5), (16, 0.25),
    (32, 1.0), (32, 0.5), (32, 0.25), (32, 0.125),
    (64, 0.5), (64, 0.25), (64, 0.125), (64, 0.0625),
]


def pareto_front(points):
    """Points: list of (prd, cr).  Front: max CR at each PRD (lower-left
    dominated points removed)."""
    pts = sorted(points)
    front = []
    best_cr = -1.0
    for prd, cr in pts:
        if cr > best_cr:
            front.append((prd, cr))
            best_cr = cr
    return front


def run(fast: bool = False):
    os.makedirs(ART, exist_ok=True)
    datasets = sorted(DATASETS) if not fast else ["mitbih", "load_power"]
    for ds in datasets:
        dom = domain_of(ds)
        base = DOMAIN_DEFAULTS[dom]
        sig = eval_signal(ds, 65536)
        points = []
        t0 = time_fn(lambda: None)  # noop baseline
        for n, frac in SWEEP:
            e = max(1, int(n * frac))
            cfg = CodecConfig(
                n=n, e=e, b1=min(base.b1, e), b2=e, mu=base.mu,
                alpha1=base.alpha1, a0_percentile=base.a0_percentile,
                scale_headroom=base.scale_headroom,
            )
            try:
                cr, prd = roundtrip_metrics(sig, tables_for(ds, cfg))
            except Exception:
                continue
            points.append((float(prd), float(cr), n, e))
        front = pareto_front([(p, c) for p, c, _, _ in points])
        # best CR within the paper's high-fidelity band (PRD <= 5%; 2% seismic)
        band = 2.0 if dom == "seismic" else 5.0
        in_band = [c for p, c in front if p <= band]
        best = max(in_band) if in_band else 0.0
        with open(os.path.join(ART, f"{ds}.json"), "w") as f:
            json.dump(
                {"dataset": ds, "domain": dom, "points": points,
                 "pareto": front, "best_cr_in_band": best, "band": band},
                f, indent=1,
            )
        emit(
            f"rd_pareto/{ds}", 0.0,
            f"best_CR@PRD<={band:.0f}%={best:.1f}x front_points={len(front)}",
        )


if __name__ == "__main__":
    run()
