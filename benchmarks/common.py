"""Shared benchmark utilities: timing, CSV output, dataset prep."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import numpy as np

from repro.core import DOMAIN_DEFAULTS, calibrate
from repro.core.calibration import DomainTables
from repro.data import make_signal
from repro.data.signals import DATASETS, domain_of

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


_TABLE_CACHE: Dict[Tuple[str, tuple], DomainTables] = {}


def tables_for(dataset: str, cfg=None) -> DomainTables:
    dom = domain_of(dataset)
    cfg = cfg or DOMAIN_DEFAULTS[dom]
    key = (dataset, tuple(sorted(vars(cfg).items())))
    if key not in _TABLE_CACHE:
        calib = np.concatenate(
            [make_signal(dataset, 65536, seed=90 + i) for i in range(4)]
        )
        _TABLE_CACHE[key] = calibrate(calib, cfg)
    return _TABLE_CACHE[key]


def eval_signal(dataset: str, n: int = 262144, seed: int = 1) -> np.ndarray:
    return make_signal(dataset, n, seed=seed)
