"""Serving front-end latency/throughput: p50/p99 vs offered load, the
saturation knee, and load-shedding behavior past it.

Drives :class:`~repro.serving.frontend.ServingFrontend` with synthetic
open-loop traffic (:mod:`repro.serving.traffic`: Poisson arrivals,
heavy-tailed sizes, the paper domains, mixed decode/encode/transcode
traffic) and sweeps offered load for two batch-formation arms:

  * **microbatch** — the deadline micro-batcher: dispatch on policy-edge
    fill or oldest-deadline slack, whichever first;
  * **batch1** — naive batch-of-one (``max_batch=1``): every request is
    its own engine dispatch, the pre-front-end serving model.

across the engine scheduling modes (sync / pipelined / sharded — sharded
only when >1 device is visible, e.g. the CI 4-fake-device leg).  For each
(mode, arm, load) point it reports p50/p95/p99 sojourn latency, achieved
goodput, and shed counts; an arm's **knee** is the highest offered load
it sustains (p99 within SLO, nothing shed, every admitted request
completed).  A final overload point runs the micro-batcher far past
saturation with a small queue bound to show explicit shedding engaging
(shed > 0, reported — never a silent drop).

The expected picture: at low load the micro-batcher's latency sits near
``SLO - flush_slack`` by construction (it trades latency *within* the
SLO for bucket fill), while batch-of-one is near the single-dispatch
floor; past batch-of-one's per-dispatch capacity its queues grow without
bound and p99 diverges, while the micro-batcher shifts to fill-triggered
full buckets and keeps going — the knee ordering the smoke run asserts.

Engines are warmed per mode before measuring: jit specializations exist
per (domain, kind, bucket-edge) shape, and a serving process reaches
steady state quickly, so knees measure scheduling, not compilation.
Everything lands in ``benchmarks/artifacts/serving/BENCH_serving.json``.
``--smoke`` is the CI guard: single-domain fixed-size stream, pipelined
mode (plus sharded when devices allow), asserting the knee ordering and
that overload sheds — the two claims the front-end exists for.

``--chaos`` runs the fault-isolation soak instead: a mixed-kind stream
with a seeded fraction of corrupted containers and injected dispatcher
faults (transient failures, device loss, latency), measuring what fault
handling *costs* — clean-request goodput under the fault rate, quarantine
and retry counters, and the byte-identity verdict for every clean result
against the offline engines.  Lands in ``BENCH_chaos.json``; with
``--smoke`` it also asserts the chaos contract (zero hangs, zero untyped
failures, zero silent drops, byte-identical clean results).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional, Tuple

import jax

from repro.serving.batch_decode import BatchDecoder
from repro.serving.batch_encode import BatchEncoder
from repro.serving.frontend import (
    FrontendConfig,
    ServingFrontend,
    policy_fill_target,
)
from repro.serving.transcode import Transcoder
from repro.serving.traffic import (
    TrafficConfig,
    build_domain_tables,
    generate,
    replay,
)

ART = "benchmarks/artifacts/serving"


def _build_engines(engine_kwargs: dict) -> dict:
    """One engine set per mode, shared by every front-end in the sweep —
    plan caches and jit specializations stay warm across arms/loads."""
    dec = BatchDecoder(**engine_kwargs)
    enc = BatchEncoder(**engine_kwargs)
    return {
        "decoder": dec, "encoder": enc,
        "transcoder": Transcoder(decoder=dec, encoder=enc),
    }


def _warm(tables, engines: dict, requests, max_batch: int) -> None:
    """Compile the batch-shape lattice the sweep will hit: per (domain,
    kind), one engine call at every policy bucket edge up to the fill
    target (engine padding rounds every micro-batch onto those edges)."""
    dec, enc, tr = (
        engines["decoder"], engines["encoder"], engines["transcoder"],
    )
    edges = []
    k = 1
    fill = policy_fill_target(dec.scheduler.policy, max_batch)
    while k <= fill:
        edges.append(k)
        k = dec.scheduler.policy.round(k + 1)
    by_dom_c: Dict[int, list] = {}
    by_dom_s: Dict[int, list] = {}
    tr_pairs: Dict[Tuple[int, int], list] = {}
    for r in requests:
        if r.kind == "decode":
            by_dom_c.setdefault(r.domain_id, []).append(r.container)
        elif r.kind == "encode":
            by_dom_s.setdefault(r.domain_id, []).append(r.signal)
        else:
            tr_pairs.setdefault(
                (r.domain_id, r.dst_domain_id), []
            ).append(r.container)
    for d, cs in by_dom_c.items():
        for k in edges:
            if len(cs) >= k:
                dec.decode(cs[:k], tables[d]).to_host()
    for d, ss in by_dom_s.items():
        for k in edges:
            if len(ss) >= k:
                enc.encode(ss[:k], tables[d]).to_host()
    for (src, dst), cs in tr_pairs.items():
        for k in edges:
            if len(cs) >= k:
                tr.transcode(
                    cs[:k], tables[src], tables[dst],
                    dst_domain_ids=[dst] * k,
                ).to_host()


def _sweep_arm(
    tables,
    engines: dict,
    loads_rps: List[float],
    *,
    arm: str,
    slo_ms: float,
    slack_ms: float,
    duration_s: float,
    max_batch: int,
    traffic: dict,
    max_queue_depth: int,
    seed: int,
) -> List[dict]:
    """Replay one traffic stream per offered load through a fresh
    front-end (shared warm engines), collecting the summary per point."""
    points = []
    for rps in loads_rps:
        cfg = TrafficConfig(
            rate=rps, duration_s=duration_s, seed=seed + int(rps), **traffic
        )
        requests = generate(cfg, tables)
        fcfg = FrontendConfig(
            max_batch=1 if arm == "batch1" else max_batch,
            max_queue_depth=max_queue_depth,
            default_slo_ms=slo_ms,
            flush_slack_ms=slack_ms,
        )
        if arm != "batch1":
            # per-point warm pass (same stream, discarded): micro-batch
            # compositions are timing-dependent, so the lattice warmup
            # can miss a shape; a steady-state service would be warm
            with ServingFrontend(tables, config=fcfg, **engines) as fe:
                replay(fe, requests)
        with ServingFrontend(tables, config=fcfg, **engines) as fe:
            report = replay(fe, requests)
            stats = fe.stats_snapshot()
        point = report.summary()
        point.update(
            offered_rps=rps,  # nominal sweep coordinate, not the estimate
            arm=arm,
            fill_target=fe.fill_target,
            batches=stats.batches,
            mean_batch=round(stats.mean_batch_size, 2),
            fill_dispatches=stats.fill_dispatches,
            deadline_dispatches=stats.deadline_dispatches,
            deadline_misses=stats.deadline_misses,
        )
        points.append(point)
        print(
            f"serving_{arm}_rps{rps:g},{point['p99_ms'] * 1e3:.1f},"
            f"p50={point['p50_ms']:.1f}ms p99={point['p99_ms']:.1f}ms "
            f"goodput={point['achieved_rps']:.0f}/s shed={point['shed']} "
            f"mean_batch={point['mean_batch']}",
            flush=True,
        )
    return points


def _knee(points: List[dict], slo_ms: float) -> float:
    """Highest offered load an arm sustains: p99 <= SLO, zero shed, and
    every admitted request completed."""
    knee = 0.0
    for p in points:
        ok = (
            p["p99_ms"] <= slo_ms
            and p["shed"] == 0
            and p["completed"] == p["submitted"]
            and p["submitted"] > 0
        )
        if ok and p["offered_rps"] > knee:
            knee = p["offered_rps"]
    return knee


def _overload_point(tables, engines: dict, *, rps: float, slo_ms: float,
                    traffic: dict, seed: int) -> dict:
    """Push the micro-batcher far past saturation with a small queue
    bound: shedding must engage (and be reported, not silent)."""
    cfg = TrafficConfig(
        rate=rps, duration_s=0.5, seed=seed,
        **{**traffic, "mix": {"decode": 1.0}},
    )
    requests = generate(cfg, tables)
    fcfg = FrontendConfig(
        max_batch=8, max_queue_depth=16, default_slo_ms=slo_ms,
        flush_slack_ms=2.0,
    )
    with ServingFrontend(tables, config=fcfg, **engines) as fe:
        report = replay(fe, requests)
    point = report.summary()
    point["queue_bound"] = fcfg.max_queue_depth
    print(
        f"serving_overload_rps{rps:g},{point['p99_ms'] * 1e3:.1f},"
        f"shed={point['shed']} of {len(requests)} "
        f"(queue bound {fcfg.max_queue_depth})",
        flush=True,
    )
    return point


def run(fast: bool = False, smoke: bool = False) -> dict:
    os.makedirs(ART, exist_ok=True)
    tables = build_domain_tables()
    slo_ms, slack_ms = 250.0, 50.0
    if smoke or fast:
        loads = [50.0, 100.0, 200.0, 400.0, 800.0, 1600.0]
        duration_s, max_batch = 1.0, 16
        # one domain, one size: the deterministic CI guard — shapes warm
        # in seconds and the knee ordering is about scheduling alone
        traffic = {
            "mix": {"decode": 0.6, "encode": 0.4},
            "fixed_windows": 8, "domains": (2,),
        }
    else:
        loads = [25.0, 50.0, 100.0, 200.0, 400.0, 800.0]
        duration_s, max_batch = 2.0, 64
        traffic = {
            "mix": {"decode": 0.6, "encode": 0.3, "transcode": 0.1},
            "median_windows": 16,
        }

    multi = len(jax.devices()) > 1
    modes = {"pipelined": {"pipeline": True, "devices": None}}
    if not (smoke or fast):
        modes["sync"] = {"pipeline": False, "devices": None}
    if multi:
        modes["sharded"] = {"pipeline": True, "devices": "auto"}

    results: dict = {
        "slo_ms": slo_ms,
        "flush_slack_ms": slack_ms,
        "loads_rps": loads,
        "duration_s": duration_s,
        "max_batch": max_batch,
        "traffic": {k: list(v) if isinstance(v, tuple) else v
                    for k, v in traffic.items()},
        "num_devices": len(jax.devices()),
        "modes": {},
        "knees": {},
    }
    engines_by_mode = {}
    for mode, engine_kwargs in modes.items():
        print(f"# mode={mode} {engine_kwargs}", flush=True)
        engines = engines_by_mode[mode] = _build_engines(engine_kwargs)
        warm_cfg = TrafficConfig(
            rate=max(loads), duration_s=0.5, seed=99, **traffic
        )
        _warm(tables, engines, generate(warm_cfg, tables), max_batch)

        results["modes"][mode] = {}
        results["knees"][mode] = {}
        for arm in ("microbatch", "batch1"):
            points = _sweep_arm(
                tables, engines, loads, arm=arm, slo_ms=slo_ms,
                slack_ms=slack_ms, duration_s=duration_s,
                max_batch=max_batch, traffic=traffic,
                max_queue_depth=1024, seed=42,
            )
            results["modes"][mode][arm] = points
            results["knees"][mode][arm] = _knee(points, slo_ms)
        print(
            f"serving_knee_{mode},0.0,"
            f"micro={results['knees'][mode]['microbatch']:g}rps "
            f"batch1={results['knees'][mode]['batch1']:g}rps",
            flush=True,
        )

    results["overload"] = _overload_point(
        tables, engines_by_mode["pipelined"], rps=2000.0, slo_ms=slo_ms,
        traffic={**traffic, "fixed_windows": traffic.get("fixed_windows", 8)},
        seed=7,
    )

    with open(os.path.join(ART, "BENCH_serving.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# wrote {os.path.join(ART, 'BENCH_serving.json')}", flush=True)

    if smoke:
        knees = results["knees"]["pipelined"]
        assert knees["microbatch"] >= knees["batch1"], (
            f"micro-batching knee {knees['microbatch']} rps fell below the "
            f"batch-of-one knee {knees['batch1']} rps"
        )
        assert knees["microbatch"] > 0, "micro-batcher sustained no load"
        assert results["overload"]["shed"] > 0, (
            "overload run shed nothing — backpressure never engaged"
        )
        print("# smoke assertions passed", flush=True)
    return results


def run_chaos(smoke: bool = False) -> dict:
    """The chaos soak as a measurement: serving under a sustained fault
    rate (corrupt containers + dispatcher sabotage), reporting what
    fault isolation costs and whether the contract held."""
    import time

    from repro.serving.traffic import replay
    from repro.testing.faults import (
        DispatcherFaultInjector,
        chaos_replay,
        offline_expected,
    )

    os.makedirs(ART, exist_ok=True)
    tables = build_domain_tables()
    # two domains with different codec configs so the wrong-table fault
    # deterministically lands on plan-mismatch
    rate = 1200.0 if smoke else 2400.0
    duration_s = 1.0 if smoke else 2.0
    corrupt_frac = 0.08
    cfg = TrafficConfig(
        rate=rate, duration_s=duration_s, fixed_windows=8,
        mix={"decode": 0.5, "encode": 0.3, "transcode": 0.2},
        domains=(2, 3), seed=31,
    )
    requests = generate(cfg, tables)
    expected = offline_expected(requests, tables)
    fcfg = FrontendConfig(
        max_batch=64, max_queue_depth=8192, default_slo_ms=600_000.0,
    )

    # clean baseline first (same stream, no corruption, no sabotage):
    # the goodput delta IS the price of the injected chaos
    with ServingFrontend(tables, config=fcfg, pipeline=True) as fe:
        replay(fe, requests)  # warm pass: compile the micro-batch shapes
    t0 = time.perf_counter()
    with ServingFrontend(tables, config=fcfg, pipeline=True) as fe:
        baseline = chaos_replay(
            fe, requests, corrupt_frac=0.0, seed=31, expected=expected,
            result_timeout_s=600.0,
        )
    baseline_wall = time.perf_counter() - t0

    inj = DispatcherFaultInjector(
        fail_on={3, 11}, latency_on={6: 0.05}, device_loss_on={17},
    )
    t0 = time.perf_counter()
    with ServingFrontend(
        tables, config=fcfg, pipeline=True, fault_injector=inj
    ) as fe:
        report = chaos_replay(
            fe, requests, corrupt_frac=corrupt_frac, seed=31,
            expected=expected, result_timeout_s=600.0,
        )
        stats = fe.stats_snapshot()
    wall = time.perf_counter() - t0

    byte_identical = report.clean_mismatches == 0
    results = {
        "requests": len(requests),
        "corrupt_frac": corrupt_frac,
        "corrupted": report.corrupted,
        "clean": report.clean,
        "clean_ok": report.clean_ok,
        "ok": report.ok,
        "poisoned": report.poisoned,
        "dispatch_failed": report.dispatch_failed,
        "rejected": report.rejected,
        "untyped_failures": report.untyped_failures,
        "hangs": report.hangs,
        "clean_mismatches": report.clean_mismatches,
        "byte_identical": byte_identical,
        "quarantined": stats.quarantined,
        "retries": stats.retries,
        "retry_successes": stats.retry_successes,
        "dispatch_failures": stats.dispatch_failures,
        "watchdog_restarts": stats.watchdog_restarts,
        "injected_faults": [[n, kind] for n, kind in inj.injected],
        "wall_s": wall,
        "clean_goodput_rps": report.clean_ok / wall if wall > 0 else 0.0,
        "baseline_wall_s": baseline_wall,
        "baseline_goodput_rps": (
            baseline.ok / baseline_wall if baseline_wall > 0 else 0.0
        ),
    }
    with open(os.path.join(ART, "BENCH_chaos.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(
        f"serving_chaos,{wall * 1e3:.0f},"
        f"clean_goodput={results['clean_goodput_rps']:.0f}/s "
        f"(baseline {results['baseline_goodput_rps']:.0f}/s) "
        f"poisoned={report.poisoned}/{report.corrupted} "
        f"retries={stats.retries} hangs={report.hangs}",
        flush=True,
    )
    print(f"# wrote {os.path.join(ART, 'BENCH_chaos.json')}", flush=True)

    if smoke:
        assert report.accounted == report.total, "silent drop detected"
        assert report.hangs == 0, "a future never resolved"
        assert report.untyped_failures == 0, (
            "an untyped error escaped the fault taxonomy"
        )
        assert report.poisoned == report.corrupted, (
            "a corrupted container did not surface as typed poison"
        )
        assert byte_identical, (
            f"{report.clean_mismatches} clean result(s) diverged from the "
            "offline engines under chaos"
        )
        assert report.clean_ok == report.clean, (
            "a clean request failed to complete"
        )
        assert len(inj.injected) >= 3, "dispatcher sabotage never fired"
        print("# chaos assertions passed", flush=True)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run + knee/shed assertions")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-isolation soak -> BENCH_chaos.json")
    args = ap.parse_args()
    if args.chaos:
        run_chaos(smoke=args.smoke)
    else:
        run(fast=args.fast, smoke=args.smoke)


if __name__ == "__main__":
    main()
