"""Fig. 10 + Fig. 11: qualitative reconstruction + parameter correlation.

Fig. 10 analog — reconstruction fidelity at matched PRD on load-power data:
feature-preservation metrics (ramp correlation, peak error) at the
aggressive operating point, demonstrating that high CR with low PRD keeps
local structure (the paper's block-artifact comparison, quantified).

Fig. 11 analog — Pearson correlation between per-dataset optimal parameter
vectors (from the RD sweep's Pareto fronts): datasets of the same domain
should cluster (paper: biosignals r >= 0.92), justifying per-domain
pretrained codec structures.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from benchmarks.common import emit, eval_signal, tables_for
from repro.core import DOMAIN_DEFAULTS, decode, encode
from repro.core.config import CodecConfig
from repro.core.metrics import prd
from repro.data.signals import domain_of

ART = "benchmarks/artifacts/reconstruction"


def _feature_metrics(x: np.ndarray, xh: np.ndarray):
    """Local-structure preservation: first-difference (ramp) correlation and
    relative peak-amplitude error."""
    dx, dxh = np.diff(x), np.diff(xh)
    ramp_corr = float(np.corrcoef(dx, dxh)[0, 1])
    peak_err = float(
        abs(np.abs(x).max() - np.abs(xh).max()) / (np.abs(x).max() + 1e-9)
    )
    return ramp_corr, peak_err


def run(fast: bool = False):
    os.makedirs(ART, exist_ok=True)

    # ---- Fig. 10: aggressive CR on load power keeps local structure -----
    sig = eval_signal("load_power", 1 << 16)
    base = DOMAIN_DEFAULTS["power"]
    rows = {}
    for label, e in (("conservative", 8), ("default", 6), ("aggressive", 2)):
        cfg = CodecConfig(n=32, e=e, b1=min(2, e), b2=e, mu=base.mu)
        tables = tables_for("load_power", cfg)
        c = encode(sig, tables)
        rec = decode(c, tables)
        p = prd(sig, rec)
        ramp, peak = _feature_metrics(sig, rec)
        rows[label] = {"cr": c.compression_ratio, "prd": p,
                       "ramp_corr": ramp, "peak_err": peak}
        emit(f"reconstruction/load_power/{label}", 0.0,
             f"CR={c.compression_ratio:.1f} PRD={p:.2f} "
             f"ramp_corr={ramp:.3f} peak_err={peak:.4f}")
    with open(os.path.join(ART, "fig10.json"), "w") as f:
        json.dump(rows, f, indent=1)

    # ---- Fig. 11: optimal-parameter correlation across datasets ---------
    vecs = {}
    for path in sorted(glob.glob("benchmarks/artifacts/rd/*.json")):
        with open(path) as f:
            r = json.load(f)
        pts = r["points"]  # (prd, cr, n, e)
        band = r["band"]
        in_band = [p for p in pts if p[0] <= band]
        if not in_band:
            continue
        best = max(in_band, key=lambda p: p[1])
        dom = r["domain"]
        dcfg = DOMAIN_DEFAULTS[dom]
        # parameter vector: the knobs the paper correlates (Table 1)
        vecs[r["dataset"]] = np.array([
            best[2], best[3], best[3] / best[2],  # N, E, E:N ratio
            dcfg.b1, dcfg.mu, dcfg.a0_percentile,
        ], dtype=np.float64)
    names = sorted(vecs)
    if len(names) >= 2:
        mat = np.zeros((len(names), len(names)))
        for i, a in enumerate(names):
            for j, b in enumerate(names):
                va, vb = vecs[a], vecs[b]
                va = (va - va.mean()) / (va.std() + 1e-12)
                vb = (vb - vb.mean()) / (vb.std() + 1e-12)
                mat[i, j] = float(np.mean(va * vb))
        # intra-domain vs inter-domain average r
        doms = {n: domain_of(n) for n in names}
        intra, inter = [], []
        for i, a in enumerate(names):
            for j, b in enumerate(names):
                if i >= j:
                    continue
                (intra if doms[a] == doms[b] else inter).append(mat[i, j])
        emit("param_correlation/summary", 0.0,
             f"intra_domain_r={np.mean(intra):.3f} "
             f"inter_domain_r={np.mean(inter):.3f} datasets={len(names)}")
        with open(os.path.join(ART, "fig11.json"), "w") as f:
            json.dump({"names": names, "matrix": mat.tolist(),
                       "intra_mean": float(np.mean(intra)),
                       "inter_mean": float(np.mean(inter))}, f, indent=1)


if __name__ == "__main__":
    run()
