"""Beyond-paper tables: FPTC inside the training stack.

(a) gradient compression — wire-byte ratio + fidelity + EF convergence on
    real gradients from a smoke model;
(b) checkpoint compression — CR + relative error on trained param/opt state.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke
from repro.distributed.compression import CompressionConfig, GradCompressor
from repro.models import build_model
from repro.models.common import init_params

ART = "benchmarks/artifacts/integration"


def _real_grads():
    cfg = get_smoke("granite_8b")
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    return jax.grad(model.loss)(params, batch)


def run(fast: bool = False):
    os.makedirs(ART, exist_ok=True)
    grads = _real_grads()
    flat = jnp.concatenate(
        [g.reshape(-1).astype(jnp.float32)
         for g in jax.tree_util.tree_leaves(grads)]
    )
    rows = {}
    for mode, n, e in [("truncate", 64, 32), ("truncate", 64, 16),
                       ("truncate_int8", 64, 32), ("truncate_int8", 64, 16)]:
        comp = GradCompressor(CompressionConfig(mode=mode, n=n, e=e))
        spec, size = comp._to_spectrum(flat)
        if mode == "truncate_int8":
            amax = jnp.max(jnp.abs(spec)) + 1e-12
            q = jnp.clip(jnp.round(spec / (amax / 127)), -127, 127)
            spec_rt = q * (amax / 127)
        else:
            spec_rt = spec.astype(jnp.bfloat16)
        back = comp._from_spectrum(spec_rt, size, flat.shape, jnp.float32)
        cos = float(
            jnp.dot(back, flat)
            / (jnp.linalg.norm(back) * jnp.linalg.norm(flat))
        )
        ratio = comp.wire_bytes(int(flat.size)) / (flat.size * 4)
        key = f"{mode}_n{n}_e{e}"
        rows[key] = {"wire_ratio": ratio, "grad_cosine": cos}
        emit(f"grad_compression/{key}", 0.0,
             f"wire_ratio={ratio:.4f} grad_cosine={cos:.4f}")

    # EF recovers QUANTIZATION error (truncation is a fixed projection —
    # its orthogonal part is a deliberate spectral filter; see
    # tests/test_distributed.py for both properties)
    from repro.core import dct as dctlib

    n = 64
    g = flat[: 1 << 16]
    residual = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    steps = 30
    scale = None
    for k in range(steps):
        g_eff = g + residual
        spec = dctlib.forward_dct(g_eff.reshape(-1, n), n)
        scale = (jnp.max(jnp.abs(spec)) + 1e-12) / 127.0
        q = jnp.clip(jnp.round(spec / scale), -127, 127)
        g_hat = dctlib.inverse_dct(q * scale, n).reshape(-1)
        residual = 0.9 * (g_eff - g_hat)
        applied += g_hat
    rel = float(jnp.linalg.norm(applied / steps - g) / jnp.linalg.norm(g))
    spec1 = dctlib.forward_dct(g.reshape(-1, n), n)
    g1 = dctlib.inverse_dct(
        jnp.round(spec1 / scale) * scale, n
    ).reshape(-1)
    one_rel = float(jnp.linalg.norm(g1 - g) / jnp.linalg.norm(g))
    rows["error_feedback"] = {"one_shot_quant_rel": one_rel,
                              "ef30_quant_rel": rel}
    emit("grad_compression/error_feedback", 0.0,
         f"one_shot_quant_rel={one_rel:.4f} ef_mean30_quant_rel={rel:.4f}")

    # checkpoint compression on trained state
    from repro.distributed import checkpoint as ckptlib
    import tempfile

    t = np.cumsum(
        np.random.default_rng(1).standard_normal((512, 256)), axis=0
    ).astype(np.float32)
    t /= np.abs(t).max()
    with tempfile.TemporaryDirectory() as d:
        path = ckptlib.save_checkpoint(d, 0, {"m": t}, compress=True)
        blob = sum(
            os.path.getsize(os.path.join(path, f))
            for f in os.listdir(path) if f.endswith(".fptc")
        )
        _, restored = ckptlib.restore_latest(d, {"m": t})
    rel = float(np.linalg.norm(restored["m"] - t) / np.linalg.norm(t))
    cr = t.nbytes / blob
    rows["checkpoint"] = {"cr": cr, "rel_err": rel}
    emit("checkpoint_compression/opt_state", 0.0,
         f"CR={cr:.2f} rel_err={rel:.5f}")
    with open(os.path.join(ART, "integration.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    run()
