"""Fig. 13: normalized runtime breakdown of decompression stages per dataset.

Times the lossless stage (SymLen Huffman decode + compaction) and the lossy
stage (dequant + inverse DCT) separately, mirroring the paper's per-kernel
latency breakdown.  The paper's observation to reproduce: low-compressibility
datasets (MIT-BIH) are lossless-dominated; smooth datasets with large N
(wind) are lossy-dominated.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_signal, tables_for
from repro.core import DOMAIN_DEFAULTS, encode
from repro.core import dct as dctlib
from repro.core import symlen as symlib
from repro.core.quantize import dequantize
from repro.data.signals import DATASETS, domain_of

ART = "benchmarks/artifacts/stage_breakdown"


@functools.partial(
    jax.jit, static_argnames=("l_max", "max_symlen", "num_symbols")
)
def _lossless(hi, lo, sl, dec_limit, dec_first, dec_rank, dec_syms, *,
              l_max, max_symlen, num_symbols):
    return symlib.unpack_symlen(
        hi, lo, sl, dec_limit, dec_first, dec_rank, dec_syms,
        l_max=l_max, max_symlen=max_symlen, num_symbols=num_symbols,
    )


@functools.partial(jax.jit, static_argnames=("n", "e", "num_windows"))
def _lossy(syms, quant, *, n, e, num_windows):
    coeffs = dequantize(syms.reshape(num_windows, e), quant)
    return dctlib.inverse_dct(coeffs, n)


def _time(fn, *a, **k):
    out = fn(*a, **k)
    jax.block_until_ready(out)
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        out = fn(*a, **k)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def run(fast: bool = False):
    os.makedirs(ART, exist_ok=True)
    datasets = ["mitbih", "wind_speed"] if fast else sorted(DATASETS)
    rows = {}
    for ds in datasets:
        tables = tables_for(ds)
        sig = eval_signal(ds, 1 << 20)
        c = encode(sig, tables)
        dev = tables.device_tables()
        hi, lo = symlib.words_to_u32(c.words)
        t_ll, syms = _time(
            _lossless, jnp.asarray(hi), jnp.asarray(lo),
            jnp.asarray(c.symlen, jnp.int32),
            dev.dec_limit, dev.dec_first, dev.dec_rank, dev.dec_syms,
            l_max=c.l_max, max_symlen=c.max_symlen,
            num_symbols=c.num_symbols,
        )
        t_ly, _ = _time(
            _lossy, syms, dev.quant, n=c.n, e=c.e, num_windows=c.num_windows
        )
        frac_ll = t_ll / (t_ll + t_ly)
        rows[ds] = {
            "lossless_ms": t_ll * 1e3, "lossy_ms": t_ly * 1e3,
            "lossless_frac": frac_ll, "cr": c.compression_ratio,
        }
        emit(
            f"stage_breakdown/{ds}", (t_ll + t_ly) * 1e6,
            f"lossless_frac={frac_ll:.2f} lossless_ms={t_ll*1e3:.1f} "
            f"lossy_ms={t_ly*1e3:.1f} CR={c.compression_ratio:.1f}",
        )
    with open(os.path.join(ART, "stages.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    run()
