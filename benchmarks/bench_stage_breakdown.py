"""Fig. 13: normalized runtime breakdown of decompression stages per dataset.

Times the lossless stage (SymLen Huffman decode + compaction) and the lossy
stage (dequant + inverse DCT) separately, mirroring the paper's per-kernel
latency breakdown.  The paper's observation to reproduce: low-compressibility
datasets (MIT-BIH) are lossless-dominated; smooth datasets with large N
(wind) are lossy-dominated.

The ``--kernels`` section adds the fused-vs-staged comparison the megakernel
PR exists for: per dataset it times the staged XLA pipeline (2 device
programs: lossless jit + lossy jit), the staged kernel pipeline (Huffman
tile pallas_call + XLA scatter + iDCT pallas_call) and the fused decode
megakernel (ONE pallas_call — huffman + compaction + LUT dequant + iDCT),
plus the encode-side twin (XLA DCT+quant+pack vs the fused encode tile).
Dispatch counts come from jaxpr inspection (pallas_call equations), not
assertion.  The results land in ``BENCH_kernels.json`` — the CI artifact
that gives the kernel-perf trajectory a baseline.  NOTE on CPU the Pallas
kernels run in interpret mode, so their *times* measure the XLA-inlined
interpretation, not TPU kernels; the structural numbers (dispatch counts,
eliminated intermediates) are the portable part.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_signal, tables_for
from repro.core import DOMAIN_DEFAULTS, encode
from repro.core import dct as dctlib
from repro.core import symlen as symlib
from repro.core.quantize import dequantize
from repro.data.signals import DATASETS, domain_of

ART = "benchmarks/artifacts/stage_breakdown"
KERNELS_ART = "benchmarks/artifacts/kernels"


@functools.partial(
    jax.jit, static_argnames=("l_max", "max_symlen", "num_symbols")
)
def _lossless(hi, lo, sl, dec_limit, dec_first, dec_rank, dec_syms, *,
              l_max, max_symlen, num_symbols):
    return symlib.unpack_symlen(
        hi, lo, sl, dec_limit, dec_first, dec_rank, dec_syms,
        l_max=l_max, max_symlen=max_symlen, num_symbols=num_symbols,
    )


@functools.partial(jax.jit, static_argnames=("n", "e", "num_windows"))
def _lossy(syms, quant, *, n, e, num_windows):
    coeffs = dequantize(syms.reshape(num_windows, e), quant)
    return dctlib.inverse_dct(coeffs, n)


def _time(fn, *a, **k):
    out = fn(*a, **k)
    jax.block_until_ready(out)
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        out = fn(*a, **k)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def _count_pallas_calls(jaxpr) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
            continue
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v)
            if hasattr(inner, "eqns"):
                total += _count_pallas_calls(inner)
    return total


def run(fast: bool = False):
    os.makedirs(ART, exist_ok=True)
    datasets = ["mitbih", "wind_speed"] if fast else sorted(DATASETS)
    rows = {}
    for ds in datasets:
        tables = tables_for(ds)
        sig = eval_signal(ds, 1 << 20)
        c = encode(sig, tables)
        dev = tables.device_tables()
        hi, lo = symlib.words_to_u32(c.words)
        t_ll, syms = _time(
            _lossless, jnp.asarray(hi), jnp.asarray(lo),
            jnp.asarray(c.symlen, jnp.int32),
            dev.dec_limit, dev.dec_first, dev.dec_rank, dev.dec_syms,
            l_max=c.l_max, max_symlen=c.max_symlen,
            num_symbols=c.num_symbols,
        )
        t_ly, _ = _time(
            _lossy, syms, dev.quant, n=c.n, e=c.e, num_windows=c.num_windows
        )
        frac_ll = t_ll / (t_ll + t_ly)
        rows[ds] = {
            "lossless_ms": t_ll * 1e3, "lossy_ms": t_ly * 1e3,
            "lossless_frac": frac_ll, "cr": c.compression_ratio,
        }
        emit(
            f"stage_breakdown/{ds}", (t_ll + t_ly) * 1e6,
            f"lossless_frac={frac_ll:.2f} lossless_ms={t_ll*1e3:.1f} "
            f"lossy_ms={t_ly*1e3:.1f} CR={c.compression_ratio:.1f}",
        )
    with open(os.path.join(ART, "stages.json"), "w") as f:
        json.dump(rows, f, indent=1)


def _decode_bucket_operands(ds: str, length: int):
    """One p2-padded single-container decode bucket + its plan."""
    from repro.serving.batch_decode import _build_decode_plan
    from repro.serving.engine import p2, symlen_bucket

    tables = tables_for(ds)
    sig = eval_signal(ds, length)
    c = encode(sig, tables)
    plan = _build_decode_plan(tables, c.plan_key, None)
    wp, nwp = p2(c.num_words), p2(c.num_windows)
    hi, lo = symlib.words_to_u32(c.words)
    hi2 = np.zeros(wp, np.uint32); hi2[:c.num_words] = hi
    lo2 = np.zeros(wp, np.uint32); lo2[:c.num_words] = lo
    sl2 = np.zeros(wp, np.int32); sl2[:c.num_words] = c.symlen
    statics = dict(
        l_max=c.l_max, max_symlen=symlen_bucket(c.max_symlen),
        num_windows=nwp, n=c.n, e=c.e,
    )
    return (plan, jnp.asarray(hi2), jnp.asarray(lo2), jnp.asarray(sl2),
            statics, tables, sig)


def run_kernels(fast: bool = True, out_path: str = None) -> dict:
    """Fused-vs-staged kernel comparison -> BENCH_kernels.json.

    Per dataset: per-stage times for the three decode pipelines and the
    two encode pipelines, plus the structural dispatch counts (pallas_call
    equations per bucket, device programs per bucket) read off the jaxprs.
    """
    import repro.kernels.ops as kops
    from repro.serving.batch_decode import _decode_bucket, _decode_bucket_math
    from repro.serving.batch_encode import (
        _build_encode_plan,
        _encode_bucket,
        _encode_bucket_kernels,
        _encode_bucket_kernels_math,
    )
    from repro.serving.engine import p2

    os.makedirs(KERNELS_ART, exist_ok=True)
    datasets = ["mitbih", "load_power"] if fast else sorted(DATASETS)
    length = 1 << 16 if fast else 1 << 20
    report = {"datasets": {}, "backend": jax.default_backend(),
              "interpret_mode": not kops.on_tpu()}

    for ds in datasets:
        plan, hi, lo, sl, statics, tables, sig = _decode_bucket_operands(
            ds, length
        )
        args = (hi, lo, sl, plan.tables, plan.lut, plan.basis)

        # staged XLA (the unfused engine arm)
        t_xla, ref = _time(
            functools.partial(_decode_bucket, use_kernels=False, **statics),
            *args,
        )
        # fused megakernel (the kernel engine arm): ONE pallas_call
        t_fused, got = _time(
            functools.partial(_decode_bucket, use_kernels=True, **statics),
            *args,
        )
        assert bool(jnp.all(ref == got)), ds  # the bit-identity contract
        # staged kernels (the pre-fusion kernel path): dense huffman kernel
        # + separate iDCT kernel, [num_symbols] intermediate through HBM
        num_symbols = statics["num_windows"] * statics["e"]

        @jax.jit
        def staged_kernels(hi, lo, sl):
            syms = kops.huffman_decode(
                hi, lo, sl, plan.tables, l_max=statics["l_max"],
                max_symlen=statics["max_symlen"], num_symbols=num_symbols,
            )
            return kops.idct_dequant(
                syms.reshape(statics["num_windows"], statics["e"]),
                plan.tables.quant, n=statics["n"], basis=plan.basis,
            )

        t_staged_k, _ = _time(staged_kernels, hi, lo, sl)

        fused_jaxpr = jax.make_jaxpr(functools.partial(
            _decode_bucket_math, use_kernels=True, **statics
        ))(*args)
        unfused_jaxpr = jax.make_jaxpr(functools.partial(
            _decode_bucket_math, use_kernels=False, **statics
        ))(*args)

        # encode side: one single-signal bucket through both arms
        cfg = tables.config
        eplan = _build_encode_plan(
            tables, (tables.domain_id, cfg.n, cfg.e, cfg.l_max), None
        )
        nw = -(-len(sig) // cfg.n)
        wp = p2(nw)
        x = np.zeros((1, wp * cfg.n), np.float32)
        x[0, : len(sig)] = sig
        counts = np.asarray([nw * cfg.e], np.int32)
        chunk = 1024
        enc_args = (jnp.asarray(x), jnp.asarray(counts), eplan.tables)
        enc_statics = dict(
            n=cfg.n, e=cfg.e, chunk_size=chunk, check_gaps=False
        )
        t_enc_xla, eref = _time(
            functools.partial(_encode_bucket, **enc_statics), *enc_args
        )
        t_enc_fused, egot = _time(
            functools.partial(_encode_bucket_kernels, **enc_statics),
            *enc_args[:2], eplan.tables, eplan.basis,
        )
        for a, b in zip(eref, egot):
            assert bool(jnp.all(a == b)), ds
        enc_jaxpr = jax.make_jaxpr(functools.partial(
            _encode_bucket_kernels_math, **enc_statics
        ))(*enc_args[:2], eplan.tables, eplan.basis)

        rec = {
            "decode": {
                "xla_ms": t_xla * 1e3,
                "staged_kernels_ms": t_staged_k * 1e3,
                "fused_ms": t_fused * 1e3,
                "fused_pallas_calls_per_bucket": _count_pallas_calls(
                    fused_jaxpr.jaxpr
                ),
                "xla_pallas_calls_per_bucket": _count_pallas_calls(
                    unfused_jaxpr.jaxpr
                ),
                # the staged kernel path: 2 pallas_calls + the XLA slice /
                # reshape programs between them, with the dense symbol
                # stream (and formerly the [max_symlen, W] tile) in HBM
                "staged_kernel_programs": 3,
                "padded_tile_hbm_roundtrip_eliminated": True,
            },
            "encode": {
                "xla_ms": t_enc_xla * 1e3,
                "fused_ms": t_enc_fused * 1e3,
                "fused_pallas_calls_per_bucket": _count_pallas_calls(
                    enc_jaxpr.jaxpr
                ),
                "bit_identical": True,
            },
        }
        report["datasets"][ds] = rec
        emit(
            f"kernels/{ds}", t_fused * 1e6,
            f"fused_ms={t_fused*1e3:.1f} xla_ms={t_xla*1e3:.1f} "
            f"staged_kernels_ms={t_staged_k*1e3:.1f} "
            f"pallas_calls=1",
        )

    out_path = out_path or os.path.join(KERNELS_ART, "BENCH_kernels.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    print(f"kernels report -> {out_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer datasets")
    ap.add_argument(
        "--kernels",
        action="store_true",
        help="run the fused-vs-staged kernel comparison and emit "
        "BENCH_kernels.json (dispatch counts + per-stage times) instead "
        "of the Fig. 13 stage breakdown",
    )
    args = ap.parse_args()
    if args.kernels:
        run_kernels(fast=args.fast)
    else:
        run(fast=args.fast)
