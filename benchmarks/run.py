"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--fast`` trims sweeps.
Mapping to the paper:
  bench_rd                  -> Fig. 8 (RD curves) + Fig. 9 (Pareto)
  bench_throughput          -> Fig. 12 (PRD-binned) + Table 3 (stability)
  bench_stage_breakdown     -> Fig. 13 (kernel runtime split)
  bench_ne_sweep            -> Fig. 14 (N x E throughput surface)
  bench_params              -> Table 1 + Table 2
  bench_compression_integration -> beyond-paper: grad/ckpt compression
  bench_roofline            -> EXPERIMENTS.md §Roofline (from dry-run)
  bench_serving             -> beyond-paper: front-end p50/p99 vs load + knee
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (e.g. rd,roofline)")
    args = ap.parse_args()

    from benchmarks import (
        bench_compression_integration,
        bench_ne_sweep,
        bench_params,
        bench_rd,
        bench_reconstruction,
        bench_roofline,
        bench_serving,
        bench_stage_breakdown,
        bench_throughput,
    )

    suite = {
        "params": bench_params.run,
        "rd": bench_rd.run,
        "throughput": bench_throughput.run,
        "serving": bench_serving.run,
        "stage_breakdown": bench_stage_breakdown.run,
        "ne_sweep": bench_ne_sweep.run,
        "reconstruction": bench_reconstruction.run,
        "integration": bench_compression_integration.run,
        "roofline": bench_roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            fn(fast=args.fast)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
